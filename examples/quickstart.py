"""Quickstart: GraphCage/TOCAB on a scale-free graph.

Runs PageRank in every paper configuration (Base → GC-push), BFS/BC/SSSP
with direction optimization, and shows the cache-model numbers behind
Figs. 9/10.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    CacheConfig, DeviceGraph, bc, bfs, build_blocked, pagerank, rmat_graph,
    simulate_pagerank_variant, spmv, sssp,
)


def main():
    print("=== GraphCage quickstart ===")
    g = rmat_graph(scale=14, edge_factor=8, seed=7, weights=True)
    print(f"graph: |V|={g.n} |E|={g.m} avg_deg={g.average_degree():.1f}")
    print(f"degree dist (paper Table 1): {g.degree_histogram()}")

    dg = DeviceGraph.from_host(g)
    t0 = time.time()
    bg = build_blocked(g, block_size=2048, direction="pull")
    bgp = build_blocked(g, block_size=2048, direction="push")
    print(f"TOCAB preprocessing: {bg.num_blocks} subgraphs "
          f"(edge budget {bg.edge_budget}, local budget {bg.local_budget}) "
          f"in {time.time()-t0:.2f}s")

    # --- PageRank, every paper variant ---
    for variant in ("base", "push", "cb", "gc-pull", "gc-push"):
        bgv = bgp if variant == "gc-push" else bg
        t0 = time.time()
        rank, iters = pagerank(dg, bgv, variant=variant, tol=1e-8)
        jnp_sum = float(rank.sum())
        print(f"PR {variant:8s}: {int(iters)} iters, Σrank={jnp_sum:.6f}, "
              f"{time.time()-t0:.2f}s")

    # --- SpMV ---
    x = jnp.ones((g.n,), jnp.float32)
    y = spmv(dg, bg, x, variant="gc-pull")
    print(f"SpMV gc-pull: |y|₁={float(jnp.abs(y).sum()):.1f}")

    # --- traversal suite ---
    depth, levels, n_push, n_pull = bfs(dg, bg, jnp.int32(0))
    reached = int((np.asarray(depth) < 10**9).sum())
    print(f"BFS: {int(levels)} levels ({int(n_push)} push, {int(n_pull)} "
          f"pull direction-optimized), reached {reached}/{g.n}")
    scores, _, _ = bc(dg, bg, jnp.int32(0))
    print(f"BC from source 0: max score={float(scores.max()):.1f}")
    dist, it = sssp(dg, bg, jnp.int32(0))
    finite = np.asarray(dist)[np.isfinite(np.asarray(dist))]
    print(f"SSSP: {int(it)} rounds, mean dist={finite.mean():.3f}")

    # --- the paper's point: cache behaviour (Figs. 9/10) ---
    cfg = CacheConfig(capacity_bytes=16 * 1024)  # thrash regime
    print("\ncache model (LRU, scaled LLC):")
    for v in ("base", "cb", "tocab"):
        r = simulate_pagerank_variant(g, v, cfg, block_size=2048)
        print(f"  {v:6s}: miss_rate={r['miss_rate']:.3f} "
              f"dram/edge={r['dram_per_edge']:.3f}")


if __name__ == "__main__":
    main()

"""Train GAT on a cora-like graph with the TOCAB aggregation backend, then
A/B the aggregation backends (flat segment-sum vs cache-blocked TOCAB).

    PYTHONPATH=src python examples/gnn_cora.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_blocked, from_edges
from repro.data.graphs import cora_like
from repro.models.gnn import GNNConfig, gnn_forward, gnn_loss_fn, init_gnn
from repro.train.optim import adamw, apply_updates, constant_schedule


def main():
    g, batch = cora_like(n=2708, m=10556, d_feat=256, n_classes=7, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")
    # TOCAB-blocked layout for the aggregation backend
    src, dst = g.edges()
    bg = build_blocked(g, block_size=512)
    print(f"TOCAB: {bg.num_blocks} subgraphs")

    cfg = GNNConfig(arch="gat", n_layers=2, d_in=256, d_hidden=8,
                    n_classes=7, n_heads=8)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    opt = adamw(constant_schedule(5e-3))
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        (loss, m), grads = jax.value_and_grad(
            lambda p: gnn_loss_fn(p, batch, cfg, bg=bg), has_aux=True)(params)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss, m["acc"]

    for i in range(101):
        params, state, loss, acc = step(params, state)
        if i % 20 == 0:
            print(f"step {i:3d} loss={float(loss):.4f} acc={float(acc):.3f}")

    # backend A/B: same params, both aggregation paths
    out_flat = gnn_forward(params, batch, cfg, bg=None)
    out_toc = gnn_forward(params, batch, cfg, bg=bg)
    print(f"agg backends max |Δ| = {float(jnp.abs(out_flat-out_toc).max()):.2e}"
          " (TOCAB ≡ flat)")


if __name__ == "__main__":
    main()

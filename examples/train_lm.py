"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Scaled-down tinyllama family (same code path as the production configs:
scan-over-layers, remat, AdamW, checkpointing, straggler watchdog).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--dim 512]
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_arch
from repro.data.tokens import synthetic_lm_batches
from repro.models.transformer import TransformerCfg, init_params, loss_fn
from repro.train.optim import adamw, cosine_schedule
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = TransformerCfg(
        name="lm-100m", n_layers=args.layers, d_model=args.dim,
        n_heads=args.dim // 64, n_kv_heads=max(1, args.dim // 128),
        head_dim=64, d_ff=args.dim * 11 // 4, vocab=8192,
        mlp_kind="swiglu", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm_ckpt_")
    trainer = Trainer(
        loss_fn=lambda p, b: loss_fn(p, b, cfg),
        optimizer=adamw(cosine_schedule(3e-4, 20, args.steps)),
        ckpt_dir=ckpt_dir, ckpt_every=100)
    p, s = trainer.init_state(params)
    p, s, start = trainer.maybe_restore(p, s)
    if start:
        print(f"resumed from step {start}")
    batches = synthetic_lm_batches(args.batch, args.seq, cfg.vocab, seed=1)
    p, s, hist = trainer.run(p, s, batches, start_step=start,
                             num_steps=args.steps, log_every=25)
    print(f"\nloss {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f}; "
          f"checkpoints in {ckpt_dir}")
    if trainer.watchdog.flagged:
        print(f"straggler steps flagged: {trainer.watchdog.flagged[:5]}")


if __name__ == "__main__":
    main()

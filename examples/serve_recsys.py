"""Serve a BERT4Rec model with batched requests: train briefly, then run
online scoring (top-k over the catalogue) and candidate retrieval.

    PYTHONPATH=src python examples/serve_recsys.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.recsys import synthetic_recsys_batches
from repro.models.bert4rec import (
    bert4rec_loss_fn, bert4rec_retrieve, bert4rec_score, init_bert4rec,
)
from repro.train.optim import adamw, apply_updates, constant_schedule


def main():
    cfg = dataclasses.replace(get_arch("bert4rec").make_smoke_cfg(),
                              vocab=5000, max_len=50)
    params = init_bert4rec(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant_schedule(1e-3))
    state = opt.init(params)
    gen = synthetic_recsys_batches(32, cfg.max_len, cfg.vocab, cfg.mask_id)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: bert4rec_loss_fn(p, batch, cfg), has_aux=True)(params)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    print("training…")
    for i in range(120):
        params, state, loss = step(params, state, next(gen))
        if i % 30 == 0:
            print(f"  step {i:3d} ce={float(loss):.4f}")

    # --- batched online serving (serve_p99-style) ---
    serve = jax.jit(lambda p, items: bert4rec_score(p, items, cfg, top_k=10))
    batch = next(gen)["items"]
    vals, idx = serve(params, batch)  # warmup/compile
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        vals, idx = serve(params, batch)
        jax.block_until_ready(vals)
    dt = (time.perf_counter() - t0) / reps
    print(f"\nonline scoring: batch={batch.shape[0]} → top-10 of "
          f"{cfg.vocab} items in {dt*1e3:.1f} ms/batch")
    print(f"  sample recs for user 0: {np.asarray(idx[0])}")

    # --- retrieval against a candidate set (retrieval_cand-style) ---
    cands = jnp.asarray(np.random.default_rng(0).choice(
        cfg.vocab, 2000, replace=False).astype(np.int32))
    rv, ri = bert4rec_retrieve(params, batch[:1], cands, cfg, top_k=5)
    print(f"retrieval: top-5 of {len(cands)} candidates → ids "
          f"{np.asarray(ri)} (scores {np.round(np.asarray(rv), 2)})")


if __name__ == "__main__":
    main()

"""Hypothesis property: ``schedule="auto"`` ≡ the flat baseline, whatever
the tuning DB pins.

The plan-resolution layer sits between every engine call and the persisted
DB — for any graph and any persisted winner, it must stay a pure dispatch
decision with no numerical surface.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    DeviceGraph, baseline_pull, build_blocked, from_edges, graph_fingerprint,
    tocab_pull,
)
from repro.tune import Candidate, entry_key
from repro.tune import db as tune_db, plan as tune_plan


@st.composite
def small_graph(draw):
    n = draw(st.integers(8, 128))
    m = draw(st.integers(4, 400))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        src, dst = np.array([0]), np.array([1])
        keep = np.array([True])
    vals = rng.random(int(keep.sum()), dtype=np.float32)
    return from_edges(n, src[keep], dst[keep], vals=vals, dedup=True)


@given(g=small_graph(), forced=st.sampled_from(["uniform", "balanced"]))
@settings(max_examples=15, deadline=None)
def test_auto_equals_baseline(tmp_path_factory, g, forced):
    tmp = tmp_path_factory.mktemp("tunedb")
    old = os.environ.get("REPRO_TUNE_DIR")
    os.environ["REPRO_TUNE_DIR"] = str(tmp)
    try:
        tune_plan.clear_cache()
        bg = build_blocked(g, block_size=32)
        key = entry_key(graph_fingerprint(g), dtype="float32",
                        workload="pagerank")
        chosen = Candidate(engine="tocab", schedule=forced, block_size=32)
        tune_db.put_entry(
            key, {"schema": tune_db.DB_SCHEMA, "graph": "prop",
                  "chosen": chosen.to_json(), "best_us": 1.0},
            tune_db.db_path())
        tune_plan.clear_cache()
        dg = DeviceGraph.from_host(g)
        x = jnp.asarray(np.linspace(0.0, 1.0, g.n, dtype=np.float32))
        out = tocab_pull(bg, x, schedule="auto")
        np.testing.assert_allclose(out, baseline_pull(dg, x),
                                   rtol=2e-5, atol=2e-5)
    finally:
        tune_plan.clear_cache()
        if old is None:
            os.environ.pop("REPRO_TUNE_DIR", None)
        else:
            os.environ["REPRO_TUNE_DIR"] = old

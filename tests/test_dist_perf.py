"""Multi-device numerical validation of the §Perf mechanisms (8-device
subprocess): distributed_topk == plain top_k, sharded MoE dispatch ==
global dispatch, binned segment sum == flat segment sum."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import use_mesh_rules
    from repro.dist.collectives import distributed_topk

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {}
    rng = np.random.default_rng(0)

    # --- distributed 2-stage top-k == plain top-k (exact) ---
    with use_mesh_rules(mesh):
        scores = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
        scores = jax.device_put(scores, NamedSharding(mesh, P("data", "model")))
        v1, i1 = jax.jit(lambda s: distributed_topk(s, 5, mesh))(scores)
        v2, i2 = jax.lax.top_k(scores, 5)
    out["topk_val_err"] = float(jnp.abs(v1 - v2).max())
    out["topk_idx_match"] = bool((np.asarray(i1) == np.asarray(i2)).all())

    # --- sharded MoE dispatch == global (lossless capacity) ---
    from repro.models.moe import MoECfg, init_moe, moe_block
    cfg_g = MoECfg(d_model=32, d_ff=64, num_experts=4, top_k=2,
                   dispatch="global", capacity_factor=16.0)
    cfg_s = dataclasses.replace(cfg_g, dispatch="sharded")
    p = init_moe(jax.random.PRNGKey(0), cfg_g)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
    with use_mesh_rules(mesh):
        o1, _ = jax.jit(lambda p, x: moe_block(p, x, cfg_g))(p, x)
        o2, _ = jax.jit(lambda p, x: moe_block(p, x, cfg_s))(p, x)
    out["moe_err"] = float(jnp.abs(o1 - o2).max())

    # --- binned segment sum == flat (under the stripe contract) ---
    from repro.models.gnn import _binned_segment_sum
    import jax.ops
    n_out, shards = 32, 4
    stripe = n_out // shards
    per = 16  # values per shard
    segs, vals = [], []
    for s in range(shards):
        segs.append(rng.integers(s * stripe, (s + 1) * stripe, per))
        vals.append(rng.standard_normal((per, 3)).astype(np.float32))
    seg = jnp.asarray(np.concatenate(segs), jnp.int32)
    val = jnp.asarray(np.concatenate(vals))
    with use_mesh_rules(mesh):
        a = jax.jit(lambda v, s: _binned_segment_sum(v, s, n_out))(val, seg)
    b = jax.ops.segment_sum(val, seg, num_segments=n_out)
    out["binned_err"] = float(jnp.abs(a - b).max())
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_distributed_topk_exact(results):
    assert results["topk_val_err"] == 0.0
    assert results["topk_idx_match"]


def test_moe_sharded_dispatch_equivalent(results):
    assert results["moe_err"] < 1e-6


def test_binned_segment_sum_equals_flat(results):
    assert results["binned_err"] < 1e-6

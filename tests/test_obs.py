"""Observability layer: registry determinism, span nesting + JSONL schema,
export fingerprints, report rendering/diffing, and the instrumentation
smoke test (the engines actually populate the expected series)."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import export, trace
from repro.obs.metrics import Registry, registry
from repro.obs.report import diff, render, render_diff


@pytest.fixture(autouse=True)
def _clean_trace():
    trace.clear()
    trace.set_sink(None)
    yield
    trace.clear()
    trace.set_sink(None)


# ------------------------------ metrics ------------------------------ #
def test_counter_gauge_deterministic_snapshot():
    r = Registry()
    for _ in range(3):
        r.counter("edges", "help text").inc(5, engine="pull")
    r.counter("edges").inc(2, engine="push")
    r.gauge("frontier").set(10, algo="bfs")
    r.gauge("frontier").set(7, algo="bfs")  # last write wins
    snap = r.snapshot()
    assert snap["edges"]["kind"] == "counter"
    assert snap["edges"]["help"] == "help text"
    assert snap["edges"]["series"] == [
        {"labels": {"engine": "pull"}, "value": 15.0},
        {"labels": {"engine": "push"}, "value": 2.0},
    ]
    assert snap["frontier"]["series"] == [
        {"labels": {"algo": "bfs"}, "value": 7.0}]
    # identical recording order-insensitivity: label order can't matter
    r2 = Registry()
    r2.counter("edges", "help text").inc(2, engine="push")
    r2.counter("edges").inc(15, engine="pull")
    assert r2.snapshot()["edges"] == snap["edges"]


def test_histogram_aggregation():
    r = Registry()
    h = r.histogram("lat", "latencies")
    for v in (0.5, 1.5, 3.0, 0.0):
        h.observe(v)
    s = h.stats()
    assert s["count"] == 4
    assert s["sum"] == pytest.approx(5.0)
    assert s["min"] == 0.0 and s["max"] == 3.0
    assert s["mean"] == pytest.approx(1.25)
    # log2 buckets: 0.5→2^-1, 1.5→2^1, 3.0→2^2, 0.0→"0"
    assert s["buckets"] == {"0": 1, "2^-1": 1, "2^1": 1, "2^2": 1}
    # snapshot is JSON-serializable and stable under a round-trip
    snap = r.snapshot()
    assert json.loads(json.dumps(snap)) == snap


def test_kind_collision_raises():
    r = Registry()
    r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")


# ------------------------------- spans ------------------------------- #
def test_span_nesting_and_jsonl_roundtrip(tmp_path):
    sink = tmp_path / "trace.jsonl"
    trace.set_sink(str(sink))
    with trace.span("outer", phase="bench"):
        with trace.span("inner") as sp:
            sp.block(jnp.ones((4,)))
            sp.set(rows=4)
    evts = trace.events()
    assert [e["name"] for e in evts] == ["inner", "outer"]  # finish order
    inner, outer = evts
    assert inner["parent"] == "outer" and inner["depth"] == 1
    assert outer["parent"] is None and outer["depth"] == 0
    assert inner["attrs"] == {"rows": 4}
    assert outer["attrs"] == {"phase": "bench"}
    assert inner["blocked_s"] >= 0.0
    assert 0.0 <= inner["dur_s"] <= outer["dur_s"]
    # JSONL sink round-trips to the identical events
    lines = [json.loads(l) for l in sink.read_text().splitlines()]
    assert lines == evts
    # span durations also land in the shared registry
    st = registry.histogram("obs.span_seconds").stats(name="inner")
    assert st is not None and st["count"] >= 1


# ------------------------------ export ------------------------------- #
def test_bench_payload_schema_and_atomic_write(tmp_path):
    payload = export.bench_payload(
        "figX", [{"name": "a", "us_per_call": 1.5}])
    assert payload["schema"] == export.BENCH_SCHEMA
    assert payload["name"] == "figX"
    fp = payload["fingerprint"]
    for key in ("jax_version", "backend", "device_count", "git_sha"):
        assert key in fp
    assert fp["device_count"] >= 1
    p = tmp_path / "BENCH_figX.json"
    export.write_json(str(p), payload)
    assert export.read_json(str(p)) == json.loads(json.dumps(payload))
    assert not list(tmp_path.glob("*.tmp"))  # atomic write cleaned up


# ------------------------------ report ------------------------------- #
def _payload(us):
    return export.bench_payload(
        "fig", [{"name": "a", "us_per_call": us, "edges_per_s": 1e6 / us}])


def test_report_render_and_diff():
    new, old = _payload(110.0), _payload(100.0)
    out = render(new)
    assert "us_per_call" in out and "a" in out
    rows = diff(new, old)
    by_metric = {r["metric"]: r for r in rows}
    assert by_metric["us_per_call"]["delta"] == pytest.approx(10.0)
    assert by_metric["us_per_call"]["pct"] == pytest.approx(10.0)
    table = render_diff(rows, only_metric="us_per_call")
    assert "+10.0%" in table


# --------------------- instrumentation smoke test --------------------- #
def test_engines_populate_registry():
    from repro.core import graph as G
    from repro.core.graph import DeviceGraph
    from repro.core.partition import build_blocked
    from repro.core import cache_model, tocab, traversal

    rng = np.random.default_rng(0)
    g = G.from_edges(64, rng.integers(0, 64, 300), rng.integers(0, 64, 300))
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=16, direction="pull")

    # the registry is process-global and other tests run BFS too — count
    # this test's iterations as a delta, not an absolute
    iters = registry.counter("traversal.iterations")

    def bfs_iters():
        return sum(s["value"] for s in iters.snapshot()["series"]
                   if dict(s["labels"]).get("algo") == "bfs")

    before = bfs_iters()
    tocab.tocab_pull(bg, jnp.ones((g.n,), jnp.float32))
    depth, levels, n_push, n_pull = traversal.bfs(dg, bg, jnp.int32(0))
    depth.block_until_ready()
    cache_model.simulate_pagerank_variant(g, "tocab", block_size=16)

    names = registry.names()
    for want in (
        "tocab.engine_traces", "tocab.blocks", "tocab.edges",
        "traversal.frontier_size", "traversal.frontier_edges",
        "traversal.iterations",
        "cache.miss_rate", "cache.dram_per_edge", "cache.simulations",
    ):
        assert want in names, f"missing metric {want}"
    # trace-time static facts for the TOCAB engine
    assert registry.gauge("tocab.blocks").value(
        engine="tocab_pull") == bg.num_blocks
    # BFS ran some iterations and the debug.callback delivered them
    total = bfs_iters() - before
    assert total >= int(levels)
    assert total == int(n_push) + int(n_pull)


def test_tocab_timed_records_throughput():
    from repro.core import graph as G
    from repro.core.partition import build_blocked
    from repro.core import tocab

    rng = np.random.default_rng(1)
    g = G.from_edges(32, rng.integers(0, 32, 100), rng.integers(0, 32, 100))
    bg = build_blocked(g, block_size=8, direction="pull")
    out = tocab.timed(tocab.tocab_pull, bg, jnp.ones((g.n,), jnp.float32))
    assert out.shape == (g.n,)
    st = registry.histogram("tocab.call_seconds").stats(engine="tocab_pull")
    assert st is not None and st["count"] >= 1
    assert registry.gauge("tocab.edges_per_s").value(engine="tocab_pull") > 0

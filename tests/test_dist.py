"""Distribution: sharding rules, multi-device collectives (subprocess with
8 fake devices), gradient compression, elastic re-mesh."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from jax.sharding import PartitionSpec as P


def test_logical_to_spec_divisibility_fallback():
    """Non-divisible dims must drop the mesh axis, never error."""
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.dist.sharding import logical_to_spec
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    # axis size 1 → everything "fits" but size<=1 → dropped → all None
    spec = logical_to_spec(("batch", "heads"), (8, 6), mesh)
    assert spec == P(None, None)


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.dist.collectives import (
        make_dp_grad_fn, init_error_feedback, ring_all_reduce)
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    out = {}

    # --- compressed DP grads ≈ exact grads ---
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.random((6, 1), dtype=np.float32))}
    batch = {"x": jnp.asarray(rng.random((4, 8, 6), dtype=np.float32)),
             "y": jnp.asarray(rng.random((4, 8, 1), dtype=np.float32))}
    residuals = init_error_feedback(params, 4)
    with mesh:
        fn_c = make_dp_grad_fn(loss_fn, mesh, "pod", compress=True)
        fn_e = make_dp_grad_fn(loss_fn, mesh, "pod", compress=False)
        g_c, res, loss_c = jax.jit(fn_c)(params, batch, residuals)
        g_e, _, loss_e = jax.jit(fn_e)(params, batch, residuals)
    err = float(jnp.abs(g_c["w"] - g_e["w"]).max())
    out["compress_err"] = err
    out["residual_norm"] = float(jnp.abs(res["w"]).sum())
    out["loss_match"] = float(abs(loss_c - loss_e))

    # --- ring all-reduce == psum ---
    x = jnp.asarray(rng.random((4, 13), dtype=np.float32))
    def body(xs):
        r = ring_all_reduce(xs[0], "pod", 4)
        p = jax.lax.psum(xs[0], "pod")
        return (r - p)[None]
    with mesh:
        diff = shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                         out_specs=P("pod"), check_rep=False)(x)
    out["ring_err"] = float(jnp.abs(diff).max())

    # --- elastic: save on 8-dev mesh, restore on 2-dev mesh ---
    import tempfile
    from repro.train import checkpoint as ckpt
    from repro.dist.elastic import make_mesh_for, reshard
    from jax.sharding import NamedSharding
    big = jax.make_mesh((4, 2), ("data", "model"))
    w = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                       NamedSharding(big, P("data", "model")))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"w": w})
        small = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                     ("data", "model"))
        restored, _, _ = ckpt.restore(
            d, {"w": w},
            shardings={"w": NamedSharding(small, P("data", "model"))})
    out["elastic_ok"] = bool(
        (np.asarray(restored["w"]) == np.arange(32.0).reshape(8, 4)).all())
    out["elastic_ndev"] = len(restored["w"].sharding.device_set)
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def subproc_results():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_compressed_grads_close_to_exact(subproc_results):
    # bf16 wire → ~3 decimal digits
    assert subproc_results["compress_err"] < 5e-3
    assert subproc_results["loss_match"] < 1e-6
    # error feedback actually carries a residual
    assert subproc_results["residual_norm"] >= 0.0


def test_ring_all_reduce_matches_psum(subproc_results):
    assert subproc_results["ring_err"] < 1e-5


def test_elastic_restore_smaller_mesh(subproc_results):
    assert subproc_results["elastic_ok"]
    assert subproc_results["elastic_ndev"] == 2

"""End-to-end behaviour tests: training converges, serving works, the
dry-run machinery compiles on a small in-process mesh."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_lm_training_converges():
    from repro.configs import get_arch
    from repro.models.transformer import init_params, loss_fn
    from repro.train.optim import adamw, cosine_schedule
    from repro.train.trainer import Trainer
    from repro.data.tokens import synthetic_lm_batches
    import dataclasses

    cfg = dataclasses.replace(get_arch("tinyllama-1.1b").make_smoke_cfg(),
                              vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tr = Trainer(loss_fn=lambda p, b: loss_fn(p, b, cfg),
                 optimizer=adamw(cosine_schedule(3e-3, 10, 80)))
    p, s = tr.init_state(params)
    batches = synthetic_lm_batches(8, 32, 128, seed=1)
    _, _, hist = tr.run(p, s, batches, num_steps=80, log_every=79,
                        log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def _gnn_step(params, state, batch, cfg, opt):
    from repro.models.gnn import gnn_loss_fn
    from repro.train.optim import apply_updates
    (loss, m), grads = jax.value_and_grad(
        lambda p: gnn_loss_fn(p, batch, cfg), has_aux=True)(params)
    upd, state = opt.update(grads, state, params)
    return apply_updates(params, upd), state, m["acc"]


def test_gnn_training_converges():
    from repro.data.graphs import cora_like
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.train.optim import adamw, constant_schedule

    g, batch = cora_like(n=300, m=1500, d_feat=32, n_classes=4, seed=1)
    cfg = GNNConfig(arch="gat", n_layers=2, d_in=32, d_hidden=8,
                    n_classes=4, n_heads=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    opt = adamw(constant_schedule(5e-3))
    state = opt.init(params)
    accs = []
    step = jax.jit(lambda p, s: _gnn_step(p, s, batch, cfg, opt))
    for _ in range(150):
        params, state, acc = step(params, state)
        accs.append(float(acc))
    assert accs[-1] > 0.7  # planted signal is learnable


def test_bert4rec_training_converges():
    import dataclasses
    from repro.configs import get_arch
    from repro.models.bert4rec import bert4rec_loss_fn, init_bert4rec
    from repro.data.recsys import synthetic_recsys_batches
    from repro.train.optim import adamw, constant_schedule, apply_updates

    cfg = dataclasses.replace(get_arch("bert4rec").make_smoke_cfg(),
                              vocab=200, max_len=16)
    params = init_bert4rec(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant_schedule(1e-2))
    state = opt.init(params)
    gen = synthetic_recsys_batches(32, 16, 200, cfg.mask_id, seed=0,
                                   step_range=3)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: bert4rec_loss_fn(p, batch, cfg), has_aux=True)(params)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    losses = []
    for _ in range(150):
        params, state, loss = step(params, state, next(gen))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3


def test_dryrun_machinery_small_mesh():
    """The exact dryrun path (cells → jit → lower → compile → roofline) on
    an 8-device subprocess mesh — proves the machinery end-to-end without
    the 512-device cost."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax
        from repro.dist.sharding import use_mesh_rules
        from repro.launch.cells import build_cell
        from repro.launch.hlo_analysis import parse_collectives, roofline_terms
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with use_mesh_rules(mesh):
            cell = build_cell("gat-cora", "full_graph_sm", mesh)
            compiled = jax.jit(cell.fn).lower(*cell.args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # jax < 0.5 returns [dict]
            cost = cost[0]
        coll = parse_collectives(compiled.as_text(), 8)
        rl = roofline_terms(cost["flops"] * 8, cost["bytes accessed"] * 8,
                            coll, 8, model_flops=cell.model_flops)
        print(json.dumps({
            "flops": cost["flops"], "dominant": rl["dominant"],
            "n_allreduce": coll.counts["all-reduce"],
        }))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
    assert out["dominant"] in ("compute", "memory", "collective")
    assert out["n_allreduce"] >= 1  # gradient reductions present


def test_dryrun_results_all_green():
    """The committed dry-run artifacts must show every non-skipped cell
    compiling on both meshes (40 cells − 3 documented skips = 37 each)."""
    d = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    recs = []
    for f in os.listdir(d):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(d, f))))
    for mesh in ("pod16x16", "pod2x16x16"):
        ok = [r for r in recs if r["mesh"] == mesh and r["ok"]]
        bad = [r for r in recs if r["mesh"] == mesh and not r["ok"]]
        assert not bad, [(r["arch"], r["shape"], r.get("error")) for r in bad]
        assert len(ok) >= 37, f"{mesh}: only {len(ok)} cells"

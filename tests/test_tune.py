"""repro.tune: fingerprints, DB round-trips, analytic prune, end-to-end
tuning, and the engines' ``schedule="auto"`` read path.

The tuner is allowed to change *where time goes*, never *what comes out*:
``schedule="auto"`` must be bit-for-bit the engine's output under the
resolved schedule, and numerically the flat baseline's answer.
"""
import os
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeviceGraph, baseline_pull, build_blocked, from_edges, graph_fingerprint,
    pagerank, rmat_graph, spmv, tocab_pull,
)
from repro.tune import (
    BUDGETS, Candidate, SearchSpace, Trial, default_candidate, device_key,
    entry_key, resolve_plan, resolve_schedule, tune,
)
from repro.tune import analytic, db as tune_db, plan as tune_plan, runner
from repro.tune.space import WORKLOADS


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    """Isolated DB dir + cold caches, restored afterwards."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    tune_plan.clear_cache()
    analytic.clear_cache()
    runner.clear_cache()
    yield tmp_path
    tune_plan.clear_cache()
    analytic.clear_cache()
    runner.clear_cache()


def hub_graph(n=512, deg=8, hubs=4, seed=0):
    """Scale-free caricature: most edges point at a few hub destinations."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = np.where(rng.random(src.shape[0]) < 0.7,
                   rng.integers(0, hubs, src.shape[0]),
                   rng.integers(0, n, src.shape[0]))
    keep = src != dst
    vals = rng.random(int(keep.sum()), dtype=np.float32)
    return from_edges(n, src[keep], dst[keep], vals=vals, dedup=True)


# --------------------------- fingerprints --------------------------- #

def test_fingerprint_stable_and_discriminating():
    a1 = rmat_graph(8, 8, seed=3, weights=True)
    a2 = rmat_graph(8, 8, seed=3, weights=True)
    b = rmat_graph(8, 8, seed=4, weights=True)
    assert graph_fingerprint(a1) == graph_fingerprint(a2)
    assert graph_fingerprint(a1) != graph_fingerprint(b)
    assert len(graph_fingerprint(a1)) == 16


def test_fingerprint_weight_independent():
    g = rmat_graph(8, 8, seed=3, weights=True)
    unweighted = rmat_graph(8, 8, seed=3, weights=False)
    assert graph_fingerprint(g) == graph_fingerprint(unweighted)


def test_fingerprint_propagates_to_device_and_blocked():
    g = rmat_graph(8, 8, seed=3, weights=True)
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=64)
    assert dg.fingerprint == graph_fingerprint(g)
    assert bg.fingerprint == graph_fingerprint(g)


# ------------------------------- DB -------------------------------- #

def test_db_roundtrip(tune_dir):
    path = tune_db.db_path()
    key = entry_key("deadbeefdeadbeef", dtype="float32", workload="pagerank")
    entry = {"schema": tune_db.DB_SCHEMA, "graph": "toy",
             "chosen": default_candidate().to_json(), "best_us": 12.5}
    tune_db.put_entry(key, entry, path)
    tune_db.clear_cache()
    got = tune_db.get_entry(key, path)
    assert got["graph"] == "toy"
    assert got["best_us"] == 12.5
    assert Candidate.from_json(got["chosen"]) == default_candidate()
    on_disk = json.loads(path.read_text()) if hasattr(path, "read_text") \
        else json.load(open(path))
    assert on_disk["schema"] == tune_db.DB_SCHEMA


def test_db_schema_mismatch_quarantined(tune_dir):
    # hardened load (repro.resilience): a wrong-schema file is moved aside
    # to TUNE_DB.json.corrupt-<ts> and an empty DB served, never an exception
    path = tune_db.db_path()
    tune_db.save({"schema": "repro.tune.db/v999", "entries": {}}, path)
    tune_db.clear_cache()
    db = tune_db.load(path)
    assert db["entries"] == {} and db["schema"] == tune_db.DB_SCHEMA
    assert any(".corrupt-" in n for n in os.listdir(os.path.dirname(path)))


def test_entry_key_shape():
    k = entry_key("abc123", dtype="float32", workload="spmv")
    assert k == f"abc123/{device_key()}/float32/spmv"


# --------------------------- search space --------------------------- #

def test_candidates_valid_and_unique():
    space = SearchSpace()
    for wl in WORKLOADS:
        cands = space.candidates(wl)
        assert len(cands) == len(set(cands))
        for c in cands:
            if c.engine == "cb":
                assert c.direction == "pull" and c.schedule == "uniform"
            if wl == "bfs":
                assert c.direction == "pull"
            if c.schedule == "balanced":
                assert c.engine == "tocab"
            assert c == Candidate.from_json(c.to_json())
    with pytest.raises(ValueError):
        space.candidates("nope")


def test_budget_presets():
    assert set(BUDGETS) == {"smoke", "small", "full"}
    smoke = SearchSpace.for_budget("smoke")
    assert len(smoke.candidates("pagerank")) <= BUDGETS["smoke"].max_trials
    with pytest.raises(ValueError):
        SearchSpace.for_budget("huge")


# --------------------------- analytic prune --------------------------- #

def test_analytic_prune_partitions_candidates(tune_dir):
    g = rmat_graph(9, 8, seed=1, weights=True)
    cands = SearchSpace().candidates("pagerank")
    kept, pruned = analytic.prune(g, cands, prune_ratio=1.0)
    assert sorted(kept + pruned, key=cands.index) == cands
    assert kept  # the best-scoring group always survives
    loose_kept, _ = analytic.prune(g, cands, prune_ratio=1e9)
    assert len(loose_kept) == len(cands)


# ------------------------- end-to-end tuning ------------------------- #

def _tiny_space():
    return SearchSpace(engines=("base", "tocab"), directions=("pull",),
                       schedules=("uniform", "balanced"), block_sizes=(128,))


def test_tune_twice_hits_db(tune_dir):
    g = rmat_graph(9, 8, seed=5, weights=True)
    first = tune({"toy": g}, workloads=("pagerank",), budget="smoke",
                 space=_tiny_space())
    assert first["new_trials"] > 0 and first["db_hits"] == 0
    second = tune({"toy": g}, workloads=("pagerank",), budget="smoke",
                  space=_tiny_space())
    assert second["new_trials"] == 0
    assert second["db_hits"] == len(second["entries"]) == 1
    entry = second["entries"][0]
    assert entry["schema"] == tune_db.DB_SCHEMA
    assert entry["graph_fp"] == graph_fingerprint(g)
    trial = Trial.from_json(entry["trials"][0])
    assert trial.us > 0 and trial.workload == "pagerank"


def _force_plan(g, candidate, workload="pagerank"):
    """Write a DB entry by hand — the read path must honour whatever the
    tuner (or an operator) persisted, so tests can pin the winner."""
    path = tune_db.db_path()
    key = entry_key(graph_fingerprint(g), dtype="float32", workload=workload)
    tune_db.put_entry(key, {"schema": tune_db.DB_SCHEMA, "graph": "forced",
                            "chosen": candidate.to_json(), "best_us": 1.0},
                      path)
    tune_plan.clear_cache()


@pytest.mark.parametrize("make_graph", [
    lambda: rmat_graph(9, 8, seed=2, weights=True),
    lambda: hub_graph(),
], ids=["random", "hub"])
def test_auto_matches_baseline(tune_dir, make_graph):
    g = make_graph()
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=128)
    _force_plan(g, Candidate(engine="tocab", schedule="balanced",
                             block_size=128))
    assert resolve_schedule(bg) == "balanced"
    rank_auto, it_auto = pagerank(dg, bg, variant="gc-pull", schedule="auto")
    rank_res, it_res = pagerank(dg, bg, variant="gc-pull",
                                schedule="balanced")
    # bit-for-bit: auto IS the resolved schedule, not a reimplementation
    assert (np.asarray(rank_auto) == np.asarray(rank_res)).all()
    assert int(it_auto) == int(it_res)
    rank_base, _ = pagerank(dg, None, variant="base")
    np.testing.assert_allclose(rank_auto, rank_base, atol=1e-7)

    x = jnp.asarray(np.random.default_rng(0).random(g.n, dtype=np.float32))
    np.testing.assert_allclose(spmv(dg, bg, x, schedule="auto"),
                               baseline_pull(dg, x), rtol=2e-5, atol=2e-5)


def test_auto_without_db_is_uniform(tune_dir):
    g = rmat_graph(8, 8, seed=6, weights=True)
    bg = build_blocked(g, block_size=64)
    assert resolve_plan(bg) is None
    assert resolve_schedule(bg) == "uniform"
    x = jnp.ones((g.n,), jnp.float32)
    out = tocab_pull(bg, x, schedule="auto")
    np.testing.assert_array_equal(out, tocab_pull(bg, x, schedule="uniform"))


def test_plan_cache_invalidates_on_db_rewrite(tune_dir):
    g = rmat_graph(8, 8, seed=7, weights=True)
    bg = build_blocked(g, block_size=64)
    assert resolve_schedule(bg) == "uniform"  # cached miss
    _force_plan(g, Candidate(engine="tocab", schedule="balanced",
                             block_size=64))
    # no manual cache clear beyond what _force_plan does: a DB rewrite
    # (new mtime) must be picked up
    assert resolve_schedule(bg) == "balanced"


def test_flat_winner_pins_uniform(tune_dir):
    g = rmat_graph(8, 8, seed=8, weights=True)
    bg = build_blocked(g, block_size=64)
    _force_plan(g, Candidate(engine="base", direction="pull"))
    # caller already committed to a blocked engine; a flat winner means
    # "no balanced dispatch", not "crash"
    assert resolve_schedule(bg) == "uniform"


def test_sibling_workload_borrowed(tune_dir):
    g = rmat_graph(8, 8, seed=9, weights=True)
    bg = build_blocked(g, block_size=64)
    _force_plan(g, Candidate(engine="tocab", schedule="balanced",
                             block_size=64), workload="spmv")
    plan = resolve_plan(bg, workload="pagerank")
    assert plan is not None and plan.source == "db:spmv"
    assert resolve_schedule(bg, workload="pagerank") == "balanced"



"""Per-arch smoke tests (assignment: reduced config, one forward/train step
on CPU, assert output shapes + no NaNs) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import transformer as tfm
from repro.models import bert4rec as b4r
from repro.models.gnn import GraphBatch, gnn_loss_fn, gnn_forward, init_gnn

KEY = jax.random.PRNGKey(0)
LM_ARCHS = [a for a, s in ARCHS.items() if s.family == "lm"]
GNN_ARCHS = [a for a, s in ARCHS.items() if s.family == "gnn"]


def _no_nan(tree):
    return not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(tree))


# ------------------------------- LM smoke ------------------------------- #
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch).make_smoke_cfg()
    params = tfm.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 33), 0, cfg.vocab)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: tfm.loss_fn(p, {"tokens": tokens}, cfg), has_aux=True)(params)
    assert loss.shape == () and float(loss) > 0
    assert _no_nan(grads) and _no_nan(loss)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    cfg = get_arch(arch).make_smoke_cfg()
    params = tfm.init_params(cfg, KEY)
    B, horizon = 2, 64
    cache = tfm.init_cache(cfg, B, horizon)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab)
    logits, cache = tfm.serve_decode(params, tok, jnp.int32(3), cache, cfg)
    assert logits.shape == (B, cfg.vocab)
    assert _no_nan(logits)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-27b"])
def test_lm_scan_equals_unrolled(arch):
    """use_scan=True and False must be numerically identical — this is what
    licenses the unrolled roofline pass."""
    cfg = get_arch(arch).make_smoke_cfg()
    params = tfm.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 17), 0, cfg.vocab)
    l1, _ = tfm.loss_fn(params, {"tokens": tokens}, cfg)
    cfg2 = dataclasses.replace(cfg, use_scan=False)
    l2, _ = tfm.loss_fn(params, {"tokens": tokens}, cfg2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=3e-4)


def test_lm_prefill_matches_decode():
    """Decoding token-by-token must match a prefill forward (KV cache
    correctness, including the sliding-window ring buffer)."""
    # fp32 + lossless dispatch (high capacity): prefill tokens can be
    # capacity-dropped while single-token decode never is — the test's
    # subject is cache correctness, not the drop policy
    cfg = dataclasses.replace(
        get_arch("mixtral-8x22b").make_smoke_cfg(), window=8,
        compute_dtype="float32", capacity_factor=8.0)
    params = tfm.init_params(cfg, KEY)
    S = 24
    tokens = jax.random.randint(KEY, (1, S), 0, cfg.vocab)
    logits_full, _ = tfm.forward(params, tokens, cfg)
    cache = tfm.init_cache(cfg, 1, horizon=S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = tfm.serve_decode(params, tokens[:, t:t + 1],
                                     jnp.int32(t), cache, cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)  # (1, S, V)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_full), rtol=1e-3, atol=1e-4)


def test_moe_balanced_dispatch_no_drop():
    """With capacity_factor ≥ E/topk·…, uniform tokens shouldn't be dropped:
    output must differ from zero for every token."""
    cfg = get_arch("granite-moe-3b-a800m").make_smoke_cfg()
    params = tfm.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (4, 33), 0, cfg.vocab)
    logits, aux = tfm.forward(params, tokens[:, :-1], cfg)
    assert _no_nan(logits)
    assert float(aux) > 0  # load-balance loss produced


# ------------------------------- GNN smoke ------------------------------- #
def _toy_batch(arch, d_in=8, n_classes=4):
    rng = np.random.default_rng(0)
    N, E = 40, 120
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    kwargs = {}
    if arch == "dimenet":
        from repro.models.gnn import build_triplets
        kj, ji, tm = build_triplets(src, dst, N, cap_per_edge=4)
        kwargs = dict(
            positions=jnp.asarray(rng.random((N, 3)).astype(np.float32) * 3),
            t_kj=jnp.asarray(kj), t_ji=jnp.asarray(ji), t_mask=jnp.asarray(tm),
            graph_ids=jnp.zeros(N, jnp.int32),
        )
        labels = jnp.asarray(rng.random(1), jnp.float32)
    else:
        labels = jnp.asarray(rng.integers(0, n_classes, N), jnp.int32)
    return GraphBatch(
        node_feat=jnp.asarray(rng.random((N, d_in)).astype(np.float32)),
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
        edge_mask=jnp.ones(E, bool), labels=labels,
        node_mask=jnp.ones(N, bool), **kwargs)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.make_smoke_cfg()
    cfg = dataclasses.replace(cfg, d_in=8,
                              graph_level=(cfg.arch == "dimenet"))
    batch = _toy_batch(cfg.arch)
    params = init_gnn(KEY, cfg)
    (loss, _), grads = jax.value_and_grad(
        lambda p: gnn_loss_fn(p, batch, cfg), has_aux=True)(params)
    assert _no_nan(grads) and _no_nan(loss)


@pytest.mark.parametrize("arch", ["gat", "gin", "sage"])
def test_gnn_tocab_agg_equals_segment(arch):
    from repro.core import build_blocked, from_edges
    rng = np.random.default_rng(1)
    batch = _toy_batch(arch)
    g = from_edges(40, np.asarray(batch.edge_src), np.asarray(batch.edge_dst))
    # NOTE: from_edges dedups nothing here but reorders — rebuild arrays in
    # the blocked graph's edge order for a fair comparison
    src, dst = g.edges()
    batch = dataclasses.replace(
        batch, edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
        edge_mask=jnp.ones(g.m, bool))
    bg = build_blocked(g, block_size=8)
    from repro.models.gnn import GNNConfig
    cfg = GNNConfig(arch=arch, n_layers=2, d_in=8, d_hidden=8, n_classes=4,
                    n_heads=2)
    params = init_gnn(KEY, cfg)
    out_flat = gnn_forward(params, batch, cfg, bg=None)
    out_toc = gnn_forward(params, batch, cfg, bg=bg)
    np.testing.assert_allclose(np.asarray(out_flat), np.asarray(out_toc),
                               rtol=2e-4, atol=2e-5)


# ------------------------------ recsys smoke ------------------------------ #
def test_bert4rec_smoke_full_softmax():
    cfg = get_arch("bert4rec").make_smoke_cfg()
    assert not cfg.sampled_softmax
    params = b4r.init_bert4rec(cfg, KEY)
    rng = np.random.default_rng(0)
    B, L = 4, cfg.max_len
    items = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    mask = jnp.asarray(rng.random((B, L)) < 0.2)
    batch = {"items": jnp.where(mask, cfg.mask_id, items), "labels": items,
             "label_mask": mask.astype(jnp.float32)}
    (loss, _), grads = jax.value_and_grad(
        lambda p: b4r.bert4rec_loss_fn(p, batch, cfg), has_aux=True)(params)
    assert _no_nan(grads) and float(loss) > 0


def test_bert4rec_sampled_softmax_path():
    cfg = dataclasses.replace(get_arch("bert4rec").make_smoke_cfg(),
                              vocab=60_000, max_masked=4, num_negatives=32)
    assert cfg.sampled_softmax
    params = b4r.init_bert4rec(cfg, KEY)
    rng = np.random.default_rng(0)
    B, L, M, K = 4, cfg.max_len, 4, 32
    batch = {
        "items": jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32),
        "mask_pos": jnp.asarray(rng.integers(0, L, (B, M)), jnp.int32),
        "pos_labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, M)), jnp.int32),
        "pos_weight": jnp.ones((B, M), jnp.float32),
        "negatives": jnp.asarray(rng.integers(0, cfg.vocab, (K,)), jnp.int32),
    }
    (loss, _), grads = jax.value_and_grad(
        lambda p: b4r.bert4rec_loss_fn(p, batch, cfg), has_aux=True)(params)
    assert _no_nan(grads) and float(loss) > 0


def test_bert4rec_score_and_retrieve():
    cfg = get_arch("bert4rec").make_smoke_cfg()
    params = b4r.init_bert4rec(cfg, KEY)
    items = jnp.zeros((3, cfg.max_len), jnp.int32)
    vals, idx = b4r.bert4rec_score(params, items, cfg, top_k=10)
    assert vals.shape == (3, 10) and idx.shape == (3, 10)
    cands = jnp.arange(500, dtype=jnp.int32)
    rv, ri = b4r.bert4rec_retrieve(params, items[:1], cands, cfg, top_k=7)
    assert rv.shape == (7,) and _no_nan(rv)


def test_binned_embedding_grad_equals_flat():
    rng = np.random.default_rng(2)
    ids = jnp.asarray(rng.integers(0, 321, (8, 16)), jnp.int32)
    g = jnp.asarray(rng.random((8, 16, 8), dtype=np.float32))
    a = b4r.binned_embedding_grad(ids, g, 321, num_bins=7)
    ref = jax.ops.segment_sum(g.reshape(-1, 8), ids.reshape(-1),
                              num_segments=321)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref), rtol=1e-6)

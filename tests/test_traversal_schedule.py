"""Traversal ``schedule=`` plumbing: balanced/auto dispatch must be
invisible in results, and Beamer α must flow from the tuning DB under
``schedule="auto"``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeviceGraph, bc, bfs, build_blocked, connected_components,
    graph_fingerprint, rmat_graph, sssp,
)
from repro.tune import Candidate, entry_key
from repro.tune import db as tune_db, plan as tune_plan


@pytest.fixture(scope="module")
def setup():
    g = rmat_graph(scale=8, edge_factor=6, seed=11, weights=True)
    return (g, DeviceGraph.from_host(g),
            DeviceGraph.from_host(g.transpose()),
            build_blocked(g, block_size=64))


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    tune_plan.clear_cache()
    yield tmp_path
    tune_plan.clear_cache()


def _pin(g, candidate, workload="bfs"):
    key = entry_key(graph_fingerprint(g), dtype="float32", workload=workload)
    tune_db.put_entry(key, {"schema": tune_db.DB_SCHEMA, "graph": "pin",
                            "chosen": candidate.to_json(), "best_us": 1.0},
                      tune_db.db_path())
    tune_plan.clear_cache()


@pytest.mark.parametrize("schedule", ["balanced", "auto"])
def test_bfs_schedules_agree(setup, tune_dir, schedule):
    g, dg, dgt, bg = setup
    ref, levels, *_ = bfs(dg, bg, jnp.int32(5))
    out, levels2, *_ = bfs(dg, bg, jnp.int32(5), schedule=schedule)
    assert (np.asarray(ref) == np.asarray(out)).all()
    assert int(levels) == int(levels2)


def test_bc_schedules_agree(setup, tune_dir):
    g, dg, dgt, bg = setup
    ref, depth, sigma = bc(dg, bg, jnp.int32(3))
    for schedule in ("balanced", "auto"):
        out, d2, s2 = bc(dg, bg, jnp.int32(3), schedule=schedule)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
        assert (np.asarray(depth) == np.asarray(d2)).all()
        np.testing.assert_allclose(sigma, s2, rtol=1e-5)


def test_sssp_schedules_agree(setup, tune_dir):
    g, dg, dgt, bg = setup
    ref, it = sssp(dg, bg, jnp.int32(5))
    for schedule in ("balanced", "auto"):
        out, it2 = sssp(dg, bg, jnp.int32(5), schedule=schedule)
        assert (np.asarray(ref) == np.asarray(out)).all()
        assert int(it) == int(it2)


def test_cc_schedules_agree(setup, tune_dir):
    g, dg, dgt, bg = setup
    ref, it = connected_components(dg, dgt, bg)
    for schedule in ("balanced", "auto"):
        out, it2 = connected_components(dg, dgt, bg, schedule=schedule)
        assert (np.asarray(ref) == np.asarray(out)).all()
        assert int(it) == int(it2)


def test_auto_with_pinned_balanced_plan(setup, tune_dir):
    g, dg, dgt, bg = setup
    _pin(g, Candidate(engine="tocab", schedule="balanced", block_size=64))
    ref, *_ = bfs(dg, bg, jnp.int32(5))
    out, *_ = bfs(dg, bg, jnp.int32(5), schedule="auto")
    assert (np.asarray(ref) == np.asarray(out)).all()


def test_alpha_override_flips_direction(setup, tune_dir):
    """α is the push↔pull switch (use_pull ⇔ m_frontier > m/α): α→∞ makes
    the threshold vanish (always pull), α→0⁺ makes it unreachable (always
    push)."""
    g, dg, dgt, bg = setup
    # (a zero-out-degree frontier still goes push: m_frontier = 0 beats no
    # positive threshold — hence ≥ levels-1, not == levels)
    _, levels, n_push, n_pull = bfs(dg, bg, jnp.int32(5), alpha=1e9)
    assert int(n_pull) >= int(levels) - 1
    _, levels2, n_push2, n_pull2 = bfs(dg, bg, jnp.int32(5), alpha=1e-9)
    assert int(n_pull2) == 0 and int(n_push2) == int(levels2)


def test_tuned_alpha_applies_under_auto(setup, tune_dir):
    g, dg, dgt, bg = setup
    _pin(g, Candidate(engine="tocab", block_size=64, alpha=1e-9))
    assert tune_plan.resolve_alpha(bg) == 1e-9
    # alpha=None + schedule="auto" takes the tuned α → all-push run,
    # bit-identical to spelling alpha=1e-9 explicitly
    d_auto, lv, n_push, n_pull = bfs(dg, bg, jnp.int32(5), schedule="auto")
    assert int(n_pull) == 0 and int(n_push) == int(lv)
    d_exp, *_ = bfs(dg, bg, jnp.int32(5), alpha=1e-9)
    assert (np.asarray(d_auto) == np.asarray(d_exp)).all()


def test_explicit_schedule_keeps_default_alpha(setup, tune_dir):
    """Without "auto", a tuned DB must not silently change behaviour."""
    g, dg, dgt, bg = setup
    _pin(g, Candidate(engine="tocab", block_size=64, alpha=1e-9))
    _, _, n_push, n_pull = bfs(dg, bg, jnp.int32(5))
    assert int(n_pull) >= 1  # paper's α=15 still engages pull

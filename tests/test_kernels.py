"""Per-kernel interpret=True validation sweeps vs the ref.py oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceGraph, baseline_pull, build_blocked, rmat_graph
from repro.kernels.tocab_spmm.ops import tocab_spmm
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.embedding_bag.ops import embedding_bag

RNG = np.random.default_rng(0)


def _t(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


# ------------------------------ tocab_spmm ------------------------------ #
@pytest.mark.parametrize("mode", ["onehot", "scatter"])
@pytest.mark.parametrize("scale,block,d", [
    (7, 32, 1), (8, 64, 8), (9, 128, 32), (8, 256, 128),
])
def test_tocab_spmm_sweep(mode, scale, block, d):
    g = rmat_graph(scale=scale, edge_factor=8, seed=scale, weights=True)
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=block)
    x = _t(g.n, d) if d > 1 else _t(g.n)
    ref = baseline_pull(dg, x)
    out = tocab_spmm(bg, x, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5)


def test_tocab_spmm_unweighted():
    g = rmat_graph(scale=7, edge_factor=6, seed=2)  # no weights
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=64)
    x = _t(g.n, 4)
    np.testing.assert_allclose(
        np.asarray(tocab_spmm(bg, x)), np.asarray(baseline_pull(dg, x)),
        rtol=5e-5, atol=5e-5)


# ---------------------------- flash attention ---------------------------- #
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 128, 64), (2, 8, 2, 256, 64), (1, 4, 1, 256, 128),
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (False, 0, 0.0), (True, 0, 30.0),
    (True, 64, 50.0),
])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, causal, window, softcap):
    q, k, v = _t(B, Hq, S, D), _t(B, Hkv, S, D), _t(B, Hkv, S, D)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_tile=64, kv_tile=64)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q, k, v = (_t(1, 2, 128, 64).astype(jnp.bfloat16) for _ in range(3))
    out = flash_attention_pallas(q, k, v, causal=True, q_tile=64, kv_tile=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2)


def test_flash_attention_tile_invariance():
    q, k, v = _t(1, 2, 256, 64), _t(1, 2, 256, 64), _t(1, 2, 256, 64)
    o1 = flash_attention_pallas(q, k, v, q_tile=64, kv_tile=64)
    o2 = flash_attention_pallas(q, k, v, q_tile=128, kv_tile=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-6, atol=2e-6)


# ----------------------------- embedding bag ----------------------------- #
@pytest.mark.parametrize("V,d,B,L,rows,btile", [
    (1000, 32, 64, 8, 256, 32), (5000, 64, 37, 5, 1024, 16),
    (128, 16, 128, 3, 64, 64),
])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(V, d, B, L, rows, btile, mode):
    tbl = _t(V, d)
    idx = jnp.asarray(RNG.integers(0, V, (B, L)), jnp.int32)
    w = jnp.asarray(RNG.random((B, L)).astype(np.float32))
    out = embedding_bag(tbl, idx, w, mode=mode, backend="pallas",
                        rows_per_block=rows, bag_tile=btile)
    ref = embedding_bag(tbl, idx, w, mode=mode, backend="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_embedding_bag_is_tocab_pattern():
    """The embedding-bag kernel's block structure IS the paper's pull TOCAB:
    accumulating per-table-block partials must equal the flat lookup."""
    V, d = 777, 24
    tbl = _t(V, d)
    idx = jnp.asarray(RNG.integers(0, V, (16, 4)), jnp.int32)
    full = embedding_bag(tbl, idx, None, backend="pallas",
                         rows_per_block=128, bag_tile=8)
    one_block = embedding_bag(tbl, idx, None, backend="pallas",
                              rows_per_block=784, bag_tile=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(one_block),
                               rtol=2e-5, atol=2e-5)


# ------------------- property-based kernel validation ------------------- #
# hypothesis is an optional dev dependency (requirements-dev.txt); without
# it only the property test is skipped, not the sweeps above.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @st.composite
    def kernel_case(draw):
        scale = draw(st.integers(5, 8))
        ef = draw(st.integers(2, 10))
        block = draw(st.sampled_from([16, 64, 256]))
        d = draw(st.sampled_from([1, 4, 8]))
        mode = draw(st.sampled_from(["onehot", "scatter"]))
        seed = draw(st.integers(0, 1000))
        return scale, ef, block, d, mode, seed

    @given(kernel_case())
    @settings(max_examples=12, deadline=None)
    def test_tocab_spmm_property(case):
        """∀ random graph/blocking/width/mode: kernel == flat oracle."""
        scale, ef, block, d, mode, seed = case
        g = rmat_graph(scale=scale, edge_factor=ef, seed=seed, weights=True)
        dg = DeviceGraph.from_host(g)
        bg = build_blocked(g, block_size=block)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(
            (g.n, d) if d > 1 else (g.n,)).astype(np.float32))
        out = tocab_spmm(bg, x, mode=mode)
        ref = baseline_pull(dg, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_tocab_spmm_property():
        pass


# ----------------------------- flash decoding ----------------------------- #
from repro.kernels.flash_attention.decode_kernel import (
    flash_decode_pallas, flash_decode_ref)


@pytest.mark.parametrize("B,Hq,Hkv,S,d,splits,kvlen,cap", [
    (2, 8, 2, 256, 64, 8, 256, 0.0),
    (1, 4, 4, 512, 64, 4, 300, 0.0),   # partial (ring) cache
    (2, 4, 1, 128, 128, 8, 128, 30.0),  # MQA + softcap
    (1, 2, 2, 128, 64, 1, 77, 0.0),    # single split degenerates cleanly
])
def test_flash_decode_sweep(B, Hq, Hkv, S, d, splits, kvlen, cap):
    q, k, v = _t(B, Hq, 1, d), _t(B, Hkv, S, d), _t(B, Hkv, S, d)
    out = flash_decode_pallas(q, k, v, kv_splits=splits, kv_len=kvlen,
                              softcap=cap)
    ref = flash_decode_ref(q, k, v, kv_len=kvlen, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_split_invariance():
    """The logsumexp merge must make the result split-count independent."""
    q, k, v = _t(1, 4, 1, 64), _t(1, 2, 256, 64), _t(1, 2, 256, 64)
    outs = [flash_decode_pallas(q, k, v, kv_splits=s) for s in (1, 4, 16)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-6, atol=2e-6)

"""Sparsity-aware load balancing: schedule invariants + engine equivalence."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    UNWEIGHTED, DeviceGraph, balanced_pull, baseline_pull, build_blocked,
    make_schedule, pagerank, rmat_graph, spmv, tocab_edge_reduce, tocab_pull,
    tocab_push,
)
from repro.core.balance import (
    BIN_DENSE, BIN_NAMES, BIN_SPARSE, bin_pull_partials, require_schedule,
)
from repro.resilience import degrade

INF = float("inf")


@pytest.fixture(scope="module")
def setup():
    g = rmat_graph(scale=9, edge_factor=8, seed=7, weights=True)
    return (
        g,
        DeviceGraph.from_host(g),
        build_blocked(g, block_size=128, direction="pull",
                      bin_thresholds="auto"),
        build_blocked(g, block_size=128, direction="push",
                      bin_thresholds="auto"),
    )


def _vals(n, d=None, seed=0):
    rng = np.random.default_rng(seed)
    shape = (n,) if d is None else (n, d)
    return jnp.asarray(rng.random(shape, dtype=np.float32))


def test_schedule_computed_at_build(setup):
    g, dg, bg, bgp = setup
    for b in (bg, bgp):
        sched = require_schedule(b)
        assert len(sched.bins) == b.num_blocks
        assert sum(sched.blocks_per_bin) == b.num_blocks
        assert sum(sched.edges_per_bin) == g.m
        # bins partition the block set
        seen = sorted(i for bin_id in range(3) for i in sched.blocks_in(bin_id))
        assert seen == list(range(b.num_blocks))
        hash(sched)  # static jit aux data must be hashable


def test_row_budget_covers_bins(setup):
    g, dg, bg, bgp = setup
    sched = bg.schedule
    n_local = np.asarray(bg.n_local)
    for bin_id in range(3):
        ids = sched.blocks_in(bin_id)
        if not ids:
            continue
        rb = sched.row_budget_per_bin[bin_id]
        assert rb >= int(n_local[list(ids)].max())
        assert rb % 8 == 0
    # push: classification rows are the window side, but the compact budget
    # must still cover compact_idx (n_local) — the edge-reduce slab width
    for b in (bg, bgp):
        sched = b.schedule
        n_local = np.asarray(b.n_local)
        for bin_id in range(3):
            ids = sched.blocks_in(bin_id)
            if not ids:
                continue
            cb = sched.compact_budget_per_bin[bin_id]
            assert cb >= int(n_local[list(ids)].max())
            assert cb % 8 == 0


def test_empty_blocks_go_sparse():
    sched = make_schedule([0, 10, 100], [1, 2, 2])
    assert sched.bins[0] == BIN_SPARSE
    assert sched.bins[2] == BIN_DENSE


@pytest.mark.parametrize("reduce", ["sum", "min", "max"])
def test_balanced_pull_matches_uniform(setup, reduce):
    g, dg, bg, _ = setup
    x = _vals(g.n)
    ref = np.asarray(tocab_pull(bg, x, reduce=reduce))
    out = np.asarray(tocab_pull(bg, x, reduce=reduce, schedule="balanced"))
    f = np.isfinite(ref)
    assert (np.isfinite(out) == f).all()
    np.testing.assert_allclose(out[f], ref[f], rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("d", [None, 5])
def test_balanced_push_matches_baseline(setup, d):
    g, dg, _, bgp = setup
    x = _vals(g.n, d)
    ref = np.asarray(baseline_pull(dg, x))
    out = np.asarray(tocab_push(bgp, x, schedule="balanced"))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_balanced_unweighted_combine(setup):
    """PageRank semantics: UNWEIGHTED ignores stored edge values and keeps
    the dense tile path eligible."""
    g, dg, bg, _ = setup
    x = _vals(g.n)
    ref = np.asarray(baseline_pull(dg, x, combine=UNWEIGHTED))
    out = np.asarray(tocab_pull(bg, x, combine=UNWEIGHTED, schedule="balanced"))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("direction", ["pull", "push"])
def test_balanced_edge_reduce(setup, direction):
    import jax
    g, dg, bg, bgp = setup
    b = bg if direction == "pull" else bgp
    rng = np.random.default_rng(3)
    ev = jnp.asarray(rng.random(g.m, dtype=np.float32))
    src, dst = g.edges()
    compact_side = dst if direction == "pull" else src
    ref = jax.ops.segment_sum(
        ev, jnp.asarray(compact_side, jnp.int32), num_segments=g.n)
    out = tocab_edge_reduce(b, ev, schedule="balanced")
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        out, tocab_edge_reduce(b, ev), rtol=2e-5, atol=2e-5)


def test_balanced_edge_reduce_push_hub():
    """Hub-destination push graph: few window rows (dst) but many compact
    rows (src) per block — regression test for sizing the edge-reduce slab
    from the window budget (compact ids spilled into adjacent blocks)."""
    from repro.core import from_edges

    n = 128
    src = np.concatenate([np.arange(1, n), np.arange(n)])
    dst = np.concatenate([np.zeros(n - 1, np.int64), (np.arange(n) + 1) % n])
    keep = src != dst
    g = from_edges(n, src[keep], dst[keep], dedup=True)
    bgp = build_blocked(g, block_size=32, direction="push")
    rng = np.random.default_rng(5)
    ev = jnp.asarray(rng.random(g.m, dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(tocab_edge_reduce(bgp, ev, schedule="balanced")),
        np.asarray(tocab_edge_reduce(bgp, ev)),
        rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("thresholds", [(INF, INF), (0.0, 0.0), (0.0, INF)])
def test_single_bin_boundaries(setup, thresholds):
    """Degenerate thresholds force every block into one bin — all-sparse,
    all-dense, all-medium — and the result must not change."""
    g, dg, _, _ = setup
    bg = build_blocked(g, block_size=128, bin_thresholds=thresholds)
    lone = [i for i, n in enumerate(bg.schedule.blocks_per_bin)
            if n == bg.num_blocks]
    assert lone, bg.schedule.blocks_per_bin
    x = _vals(g.n)
    ref = np.asarray(baseline_pull(dg, x))
    out = np.asarray(tocab_pull(bg, x, schedule="balanced"))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_pallas_dense_bin_grid(setup):
    """The Pallas tile kernel on the dense bin only (bin-aware grid)."""
    g, dg, _, _ = setup
    bg = build_blocked(g, block_size=64, bin_thresholds=(0.0, 0.0))
    assert bg.schedule.blocks_per_bin[BIN_DENSE] == bg.num_blocks
    x = _vals(g.n)
    ref = np.asarray(baseline_pull(dg, x))
    out = np.asarray(balanced_pull(bg, x, dense_impl="pallas"))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_bin_partials_shape(setup):
    g, dg, bg, _ = setup
    x = _vals(g.n)
    sched = bg.schedule
    for bin_id in range(3):
        sub = bin_pull_partials(bg, bin_id, x)
        if not sched.blocks_in(bin_id):
            assert sub is None
            continue
        k = len(sched.blocks_in(bin_id))
        rb = min(sched.row_budget_per_bin[bin_id], bg.local_budget)
        assert sub.shape == (k, rb)


@pytest.mark.skipif(
    degrade.fallback_allowed("slab", None),
    reason="REPRO_RESILIENCE_FALLBACK degrades the missing-schedule error "
           "to the reference rung instead of raising")
def test_missing_schedule_raises(setup):
    g, dg, _, _ = setup
    bg = build_blocked(g, block_size=128, classify=False)
    assert bg.schedule is None
    with pytest.raises(ValueError, match="BlockSchedule"):
        tocab_pull(bg, _vals(g.n), schedule="balanced")


def test_pagerank_balanced(setup):
    g, dg, bg, _ = setup
    r_u, it_u = pagerank(dg, bg, variant="gc-pull", tol=1e-8)
    r_b, it_b = pagerank(dg, bg, variant="gc-pull", tol=1e-8,
                         schedule="balanced")
    # per-bin reassociation may shift convergence by an iteration near tol
    assert abs(int(it_b) - int(it_u)) <= 1
    np.testing.assert_allclose(np.asarray(r_b), np.asarray(r_u),
                               rtol=1e-5, atol=1e-7)


def test_spmv_balanced(setup):
    g, dg, bg, _ = setup
    x = _vals(g.n)
    np.testing.assert_allclose(
        np.asarray(spmv(dg, bg, x, variant="gc-pull", schedule="balanced")),
        np.asarray(spmv(dg, bg, x, variant="gc-pull")),
        rtol=2e-5, atol=2e-5)


def test_timed_tolerates_pytree_returns(setup):
    """`timed()` must block on engines returning pytrees, not just arrays."""
    from repro.core.tocab import timed
    g, dg, bg, _ = setup
    x = _vals(g.n)
    out = timed(
        lambda b, v: {"rank": tocab_pull(b, v), "iters": 3, "note": "ok"},
        bg, x, engine="pytree_engine")
    assert out["iters"] == 3 and out["rank"].shape == (g.n,)


def test_obs_bin_counters(setup):
    from repro.obs.metrics import registry
    g, dg, bg, _ = setup
    tocab_pull(bg, _vals(g.n), schedule="balanced")
    snap = registry.snapshot()
    assert "tocab.balance.bin_blocks" in snap
    labels = {tuple(sorted(s["labels"].items()))
              for s in snap["tocab.balance.bin_blocks"]["series"]}
    assert any(("bin", name) in lab for name in BIN_NAMES for lab in labels)

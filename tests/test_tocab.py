"""Engine equivalence: TOCAB == baseline across semirings/shapes (§7 item 3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeviceGraph, baseline_pull, baseline_push, build_blocked, cb_pull,
    rmat_graph, tocab_pull, tocab_push,
)
from repro.core.tocab import (
    blocked_edge_values, tocab_edge_reduce, tocab_gather_src,
)


@pytest.fixture(scope="module")
def setup():
    g = rmat_graph(scale=9, edge_factor=8, seed=7, weights=True)
    return (
        g,
        DeviceGraph.from_host(g),
        build_blocked(g, block_size=128, direction="pull"),
        build_blocked(g, block_size=128, direction="push"),
    )


def _vals(n, d=None, seed=0):
    rng = np.random.default_rng(seed)
    shape = (n,) if d is None else (n, d)
    return jnp.asarray(rng.random(shape, dtype=np.float32))


@pytest.mark.parametrize("d", [None, 3, 16])
def test_sum_semiring(setup, d):
    g, dg, bg, bgp = setup
    x = _vals(g.n, d)
    ref = baseline_pull(dg, x)
    np.testing.assert_allclose(tocab_pull(bg, x), ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(cb_pull(bg, x), ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(tocab_push(bgp, x), ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("reduce", ["min", "max"])
def test_minmax_semiring(setup, reduce):
    g, dg, bg, bgp = setup
    x = _vals(g.n)
    ref = np.asarray(baseline_pull(dg, x, reduce=reduce))
    out = np.asarray(tocab_pull(bg, x, reduce=reduce))
    finite = np.isfinite(ref)
    assert (np.isfinite(out) == finite).all()
    np.testing.assert_allclose(out[finite], ref[finite], rtol=1e-6)


def test_combine_minplus(setup):
    """min-plus semiring (SSSP relaxation step)."""
    g, dg, bg, _ = setup
    x = _vals(g.n)
    plus = lambda d, w: d + w
    ref = baseline_pull(dg, x, reduce="min", combine=plus)
    out = tocab_pull(bg, x, reduce="min", combine=plus)
    r, o = np.asarray(ref), np.asarray(out)
    f = np.isfinite(r)
    np.testing.assert_allclose(o[f], r[f], rtol=1e-6)


def test_dynamic_edge_values(setup):
    """GNN path: per-edge dynamic values through the blocked layout."""
    g, dg, bg, _ = setup
    rng = np.random.default_rng(3)
    ev = jnp.asarray(rng.random(g.m, dtype=np.float32))
    # edge-value reduce == flat segment sum by dst
    src, dst = g.edges()
    import jax
    ref = jax.ops.segment_sum(ev, jnp.asarray(dst, jnp.int32), num_segments=g.n)
    out = tocab_edge_reduce(bg, ev, reduce="sum")
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
    # round trip: flat → blocked slabs → (masked) flat
    slab = blocked_edge_values(bg, ev)
    mask = np.asarray(bg.edge_mask)
    flat_back = np.zeros(g.m, np.float32)
    flat_back[np.asarray(bg.edge_perm)[mask]] = np.asarray(slab)[mask]
    np.testing.assert_allclose(flat_back, ev, rtol=0)


def test_gather_src_matches_flat(setup):
    g, dg, bg, _ = setup
    x = _vals(g.n, 4)
    src, _ = g.edges()
    ref = np.asarray(x)[src]
    out = np.asarray(tocab_gather_src(bg, x))
    np.testing.assert_allclose(out, ref, rtol=0)


def test_push_pull_same_math(setup):
    g, dg, bg, bgp = setup
    x = _vals(g.n)
    np.testing.assert_allclose(
        baseline_push(dg, x), baseline_pull(dg, x), rtol=1e-6)


def test_untouched_vertices_identity():
    """Vertices with no in-edges: 0 for sum, ±inf for min/max."""
    import repro.core as c
    g = c.from_edges(8, np.array([0, 1]), np.array([2, 2]))
    bg = c.build_blocked(g, block_size=4)
    x = jnp.arange(8, dtype=jnp.float32)
    s = np.asarray(c.tocab_pull(bg, x))
    assert s[2] == pytest.approx(1.0) and (s[[0, 1, 3, 4, 5, 6, 7]] == 0).all()
    mn = np.asarray(c.tocab_pull(bg, x, reduce="min"))
    assert np.isinf(mn[[0, 1, 3]]).all() and mn[2] == 0.0


@pytest.mark.parametrize("block_size", [32, 128])
def test_2d_blocking_equals_baseline(setup, block_size):
    """Paper §3.1 ablation: 2D blocking is numerically identical (and
    produces quadratically more tiles — the paper's overhead argument)."""
    from repro.core.ablations import build_blocked_2d, tocab_pull_2d
    g, dg, bg, _ = setup
    b2 = build_blocked_2d(g, block_size=block_size)
    x = _vals(g.n)
    np.testing.assert_allclose(
        np.asarray(tocab_pull_2d(b2, x)), np.asarray(baseline_pull(dg, x)),
        rtol=2e-5, atol=2e-5)
    assert b2.tiles_per_side ** 2 >= bg.num_blocks ** 2 // 4


@pytest.mark.parametrize("num_bins", [4, 32])
def test_propagation_blocking_equals_baseline(setup, num_bins):
    from repro.core.ablations import propagation_blocking_pull
    g, dg, bg, _ = setup
    x = _vals(g.n)
    np.testing.assert_allclose(
        np.asarray(propagation_blocking_pull(dg, x, num_bins=num_bins)),
        np.asarray(baseline_pull(dg, x)), rtol=2e-5, atol=2e-5)

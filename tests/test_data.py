"""Data pipelines: token stream, neighbor sampler, recsys batches."""
import numpy as np

from repro.core import rmat_graph
from repro.data.sampler import NeighborSampler
from repro.data.tokens import synthetic_lm_batches
from repro.data.recsys import make_cloze_batch
from repro.data.graphs import molecule_batch, cora_like


def test_token_batches_shapes_and_determinism():
    b1 = next(synthetic_lm_batches(4, 16, 100, seed=7))
    b2 = next(synthetic_lm_batches(4, 16, 100, seed=7))
    assert b1["tokens"].shape == (4, 17)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert b1["tokens"].max() < 100
    ga = next(synthetic_lm_batches(4, 16, 100, seed=7, grad_accum=2))
    assert ga["tokens"].shape == (2, 4, 17)


def test_neighbor_sampler_static_shapes():
    g = rmat_graph(scale=10, edge_factor=8, seed=3)
    rng = np.random.default_rng(0)
    feats = rng.random((g.n, 8), dtype=np.float32)
    labels = rng.integers(0, 4, g.n)
    s = NeighborSampler(g, feats, labels, sample_sizes=(5, 3), seed=1)
    b1 = s.sample(16)
    b2 = s.sample(16)
    # static shapes across draws (jit-stability)
    assert b1.node_feat.shape == b2.node_feat.shape == (16 + 80 + 240, 8)
    assert b1.edge_src.shape == (80 + 240,)
    N, E = NeighborSampler.batch_shapes(16, (5, 3), 8)
    assert N == 336 and E == 320
    # loss mask covers exactly the seeds
    assert int(np.asarray(b1.node_mask).sum()) == 16
    # edges connect consecutive layers (src slot in deeper layer)
    src = np.asarray(b1.edge_src)
    dst = np.asarray(b1.edge_dst)
    assert (src >= 16).all() and (dst < 16 + 80).all()


def test_sampler_respects_graph_topology():
    """Sampled neighbours must actually be in-neighbours in G (or self)."""
    g = rmat_graph(scale=8, edge_factor=4, seed=5)
    rng = np.random.default_rng(0)
    s = NeighborSampler(g, rng.random((g.n, 4), dtype=np.float32),
                        rng.integers(0, 3, g.n), sample_sizes=(4,), seed=2)
    seeds = rng.integers(0, g.n, 8)
    nbrs = s._sample_neighbors(seeds, 4)
    gt = g.transpose()
    for i, v in enumerate(seeds):
        in_nbrs = set(gt.colidx[gt.rowptr[v]:gt.rowptr[v + 1]].tolist())
        for u in nbrs[i]:
            assert int(u) in in_nbrs or int(u) == int(v)


def test_cloze_batch():
    rng = np.random.default_rng(0)
    b = make_cloze_batch(rng, 8, 20, vocab=500, mask_id=500)
    assert b["items"].shape == (8, 20)
    m = np.asarray(b["label_mask"]) > 0
    assert (np.asarray(b["items"])[m] == 500).all()
    assert (np.asarray(b["labels"]) < 500).all()
    assert m[:, -1].all()  # final position always masked


def test_molecule_batch_triplets_consistent():
    b = molecule_batch(n_graphs=4, nodes_per=6, d_feat=4, seed=0)
    src = np.asarray(b.edge_src)
    dst = np.asarray(b.edge_dst)
    kj = np.asarray(b.t_kj)
    ji = np.asarray(b.t_ji)
    tm = np.asarray(b.t_mask)
    # triplet invariant: dst of edge kj == src of edge ji
    assert (dst[kj[tm]] == src[ji[tm]]).all()
    # no self-triplet: src of kj != dst of ji
    assert (src[kj[tm]] != dst[ji[tm]]).all()


def test_cora_like_learnable_signal():
    g, batch = cora_like(n=200, m=800, d_feat=32, n_classes=4)
    feats = np.asarray(batch.node_feat)
    labels = np.asarray(batch.labels)
    # planted signal: label-indexed feature dimension is shifted up
    boosted = feats[np.arange(g.n), labels % 32]
    assert boosted.mean() > feats.mean() + 1.0

"""Resilience layer: chaos injection, the degradation ladder, retry/timeout
policies, hardened checkpoint/tune-DB IO, and graph structural validation.

The invariant under test throughout: faults change *where the work runs*
(ladder rung, retry attempt, rebuilt DB), never *what comes out* — the
fallback result must be bit-identical to the engine it lands on, and IO
recovery must never destroy good data.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeviceGraph, build_blocked, from_edges, graph_fingerprint, pagerank,
    rmat_graph, tocab_pull,
)
from repro.core.graph import Graph, GraphValidationError, validate_graph
from repro.obs.metrics import registry as _obs
from repro.resilience import chaos, degrade
from repro.resilience.chaos import ChaosError
from repro.resilience.retry import Policy, call_with_timeout, retry
from repro.train import checkpoint as ckpt
from repro.tune import db as tune_db
from repro.tune import plan as tune_plan
from repro.tune import analytic, runner, tuner
from repro.tune.space import Candidate, SearchSpace, TrialBudget


@pytest.fixture(autouse=True)
def clean_resilience(monkeypatch):
    """Each test starts with chaos disarmed (even under the chaos-smoke CI
    env — these tests inject their own faults) and no memoized verdicts."""
    monkeypatch.delenv(chaos.ENV_SPEC, raising=False)
    monkeypatch.delenv(chaos.ENV_SITES, raising=False)
    monkeypatch.delenv(degrade.ENV_FALLBACK, raising=False)
    chaos.reset()
    degrade.clear()
    yield
    chaos.reset()
    degrade.clear()


def small_graph(seed=0, scale=7):
    return rmat_graph(scale=scale, edge_factor=6, seed=seed, weights=True)


# ------------------------------ chaos -------------------------------- #

def test_chaos_deterministic_by_seed():
    """Same seed → same fault pattern; different seed → different pattern."""

    def pattern(seed):
        chaos.reset()
        chaos.configure(seed=seed, rate=0.3, sites={"s"})
        fired = []
        for _ in range(200):
            try:
                chaos.maybe_raise("s")
                fired.append(False)
            except ChaosError:
                fired.append(True)
        return fired

    p7a, p7b, p8 = pattern(7), pattern(7), pattern(8)
    assert p7a == p7b
    assert p7a != p8
    assert 20 < sum(p7a) < 100  # rate 0.3 over 200 draws


def test_chaos_spec_and_env_parsing(monkeypatch):
    cfg = chaos.configure_spec("42:0.5")
    assert (cfg.seed, cfg.rate) == (42, 0.5)
    assert cfg.sites == chaos.DEFAULT_SITES
    assert chaos.enabled()
    chaos.reset()

    monkeypatch.setenv(chaos.ENV_SPEC, "99:0.25")
    monkeypatch.setenv(chaos.ENV_SITES, "a,b")
    chaos.reset()  # force env re-read
    assert chaos.enabled()
    assert chaos.active_for("a") and chaos.active_for("b")
    assert not chaos.active_for("kernel.tocab_fused")

    monkeypatch.setenv(chaos.ENV_SPEC, "nonsense")
    chaos.reset()
    with pytest.raises(ValueError, match="REPRO_CHAOS"):
        chaos.enabled()


def test_chaos_inject_queue():
    chaos.inject("q", times=2)
    for _ in range(2):
        with pytest.raises(ChaosError):
            chaos.maybe_raise("q")
    chaos.maybe_raise("q")  # queue drained, rate not armed

    class Boom(RuntimeError):
        pass

    chaos.inject("q", exc=Boom("custom"))
    with pytest.raises(Boom):
        chaos.maybe_raise("q")


def test_opt_in_sites_not_default():
    """Rate-based injection at the sites that have no recovery path must be
    opt-in, or a chaos run manufactures unhandled crashes."""
    for site in ("kernel.tocab_slab", "tune.trial",
                 "kernel.tocab_fused.op", "kernel.tocab_spmm.op"):
        assert site not in chaos.DEFAULT_SITES
        assert site in chaos.KNOWN_SITES


# --------------------------- retry / timeout --------------------------- #

def test_retry_recovers_and_counts():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    pol = Policy(max_attempts=3, base_delay=0.001)
    before = _obs.counter("resilience.retries").value(
        site="t", error="OSError") or 0
    assert pol.call(flaky, site="t") == "ok"
    assert len(calls) == 3
    assert _obs.counter("resilience.retries").value(
        site="t", error="OSError") == before + 2


def test_retry_exhaustion_reraises():
    pol = Policy(max_attempts=2, base_delay=0.001)
    before = _obs.counter("resilience.retry_exhausted").value(site="x") or 0
    with pytest.raises(OSError, match="always"):
        pol.call(lambda: (_ for _ in ()).throw(OSError("always")), site="x")
    assert _obs.counter("resilience.retry_exhausted").value(
        site="x") == before + 1


def test_retry_does_not_catch_unlisted():
    pol = Policy(max_attempts=5, base_delay=0.001, retry_on=(OSError,))
    calls = []

    def bug():
        calls.append(1)
        raise ValueError("logic bug, not transient")

    with pytest.raises(ValueError):
        pol.call(bug, site="y")
    assert len(calls) == 1  # no retries for non-transient errors


def test_retry_decorator():
    state = {"n": 0}

    @retry(site="deco", max_attempts=2, base_delay=0.001)
    def fn(x):
        state["n"] += 1
        if state["n"] == 1:
            raise OSError
        return x + 1

    assert fn(1) == 2
    assert fn.policy.max_attempts == 2


def test_call_with_timeout():
    import time

    assert call_with_timeout(lambda: 5, None) == 5
    assert call_with_timeout(lambda: 5, 10.0) == 5
    with pytest.raises(TimeoutError):
        call_with_timeout(time.sleep, 0.05, 5.0)
    with pytest.raises(ZeroDivisionError):  # worker errors re-raise
        call_with_timeout(lambda: 1 / 0, 10.0)


def test_deterministic_jitter():
    pol = Policy(base_delay=0.05)
    assert pol.delay("s", 1) == pol.delay("s", 1)
    assert pol.delay("s", 1) != pol.delay("s", 2)


# -------------------------- degradation ladder -------------------------- #

def test_fallback_allowed_semantics(monkeypatch):
    assert degrade.fallback_allowed("fused", True) is True
    assert degrade.fallback_allowed("auto", False) is False
    assert degrade.fallback_allowed("auto", None) is True
    assert degrade.fallback_allowed("fused", None) is False
    monkeypatch.setenv(degrade.ENV_FALLBACK, "1")
    assert degrade.fallback_allowed("fused", None) is True


def test_fused_fallback_bit_identical_and_memoized():
    g = small_graph(seed=11)
    bg = build_blocked(g, block_size=32)
    x = jnp.asarray(np.random.default_rng(0).random(g.n, dtype=np.float32))
    want = np.asarray(tocab_pull(bg, x, impl="slab"))

    before = _obs.counter("resilience.fallbacks").value(
        site="tocab_pull", error="ChaosError",
        **{"from": "fused", "to": "slab"}) or 0
    chaos.inject("kernel.tocab_fused")
    got = np.asarray(tocab_pull(bg, x, impl="fused", allow_fallback=True))
    np.testing.assert_array_equal(got, want)
    assert _obs.counter("resilience.fallbacks").value(
        site="tocab_pull", error="ChaosError",
        **{"from": "fused", "to": "slab"}) == before + 1

    # the verdict is memoized for this (graph, site): later auto/fused
    # dispatches start at slab instead of re-failing
    assert degrade.apply_verdict(bg.fingerprint, "tocab_pull",
                                 "fused") == "slab"


def test_ladder_reaches_reference():
    g = small_graph(seed=12)
    bg = build_blocked(g, block_size=32)
    x = jnp.asarray(np.random.default_rng(1).random(g.n, dtype=np.float32))
    want = np.asarray(tocab_pull(bg, x, impl="slab"))

    eng = _obs.counter("tocab.engine_traces")
    r0 = eng.value(engine="tocab_pull_reference", direction="pull")
    chaos.inject("kernel.tocab_fused")
    chaos.inject("kernel.tocab_slab")
    got = np.asarray(tocab_pull(bg, x, impl="fused", allow_fallback=True))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    assert eng.value(engine="tocab_pull_reference", direction="pull") > r0


def test_no_fallback_without_opt_in():
    g = small_graph(seed=13)
    bg = build_blocked(g, block_size=32)
    x = jnp.ones((g.n,), jnp.float32)
    chaos.inject("kernel.tocab_fused")
    with pytest.raises(ChaosError):
        tocab_pull(bg, x, impl="fused", allow_fallback=False)


def test_pagerank_auto_fallback_acceptance(tmp_path, monkeypatch):
    """ISSUE acceptance: with chaos forcing fused kernel dispatch to fail,
    ``pagerank(..., impl="auto")`` (resolved to fused by the tuning DB)
    completes, bit-identical to ``impl="slab"``, and the obs snapshot
    records the fallback."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    tune_plan.clear_cache()
    g = small_graph(seed=14, scale=8)
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=64)
    # a tuned entry whose winner is the fused tocab engine → auto = fused
    cand = Candidate(engine="tocab", direction="pull", schedule="uniform",
                     impl="fused", block_size=64)
    key = tune_db.entry_key(graph_fingerprint(g), workload="pagerank")
    tune_db.put_entry(key, {"chosen": cand.to_json(), "workload": "pagerank"})

    want, it_want = pagerank(dg, bg, impl="slab", max_iters=30)

    chaos.configure(seed=5, rate=1.0, sites={"kernel.tocab_fused"})
    got, it_got = pagerank(dg, bg, impl="auto", max_iters=30)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(it_got) == int(it_want)

    snap = _obs.snapshot()
    assert "resilience.fallbacks" in snap
    series = snap["resilience.fallbacks"]["series"]
    assert any(s["labels"].get("site") == "tocab_pull" and
               s["labels"].get("from") == "fused" for s in series)
    tune_plan.clear_cache()


# ------------------------------- tuner -------------------------------- #

TEST_BUDGET = TrialBudget("test", warmup=0, reps=1, prune_ratio=100.0,
                          max_trials=8)
TEST_SPACE = SearchSpace(engines=("tocab",), directions=("pull",),
                         schedules=("uniform",), impls=("slab",),
                         block_sizes=(32, 64))


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    for mod in (tune_plan, analytic, runner):
        mod.clear_cache()
    yield tmp_path
    for mod in (tune_plan, analytic, runner):
        mod.clear_cache()


def test_poisoned_candidate_skipped(tune_dir):
    g = small_graph(seed=15)
    chaos.inject("tune.trial")  # first trial of the sweep crashes
    e1 = tuner.tune_graph(g, "tg", space=TEST_SPACE, budget=TEST_BUDGET)
    assert len(e1["skipped"]) == 1
    bad_key = Candidate.from_json(e1["skipped"][0]["candidate"]).key()
    key = tune_db.entry_key(graph_fingerprint(g), workload="pagerank")
    assert bad_key in tune_db.poisoned_for(key)

    # re-tune: the poisoned candidate is skipped upfront, not re-run
    e2 = tuner.tune_graph(g, "tg", space=TEST_SPACE, budget=TEST_BUDGET,
                          force=True)
    assert e2["poisoned_skipped"] == [bad_key]
    assert not e2["skipped"]
    assert all(t["candidate"]["block_size"] !=
               e1["skipped"][0]["candidate"]["block_size"]
               for t in e2["trials"])


def test_trial_timeout(tune_dir):
    g = small_graph(seed=16)
    cand = Candidate(engine="tocab", direction="pull", block_size=32)
    with pytest.raises(TimeoutError):
        runner.run_trial(g, cand, budget=TEST_BUDGET, timeout=1e-4)


# ------------------------------ tune DB -------------------------------- #

def test_db_corrupt_json_quarantined(tune_dir):
    path = tune_db.db_path()
    os.makedirs(tune_dir, exist_ok=True)
    with open(path, "w") as f:
        f.write("{definitely not json")
    tune_db.clear_cache()
    before = _obs.counter("tune.db_recovered").value(reason="corrupt") or 0
    db = tune_db.load(path)
    assert db["entries"] == {}
    assert db["schema"] == tune_db.DB_SCHEMA
    quarantined = [n for n in os.listdir(tune_dir) if ".corrupt-" in n]
    assert len(quarantined) == 1
    assert _obs.counter("tune.db_recovered").value(
        reason="corrupt") == before + 1
    # the DB keeps working after recovery
    tune_db.put_entry("k", {"chosen": {}})
    assert tune_db.get_entry("k", path) is not None


def test_db_schema_mismatch_quarantined(tune_dir):
    path = tune_db.db_path()
    with open(path, "w") as f:
        json.dump({"schema": "something/else", "entries": {"k": {}}}, f)
    tune_db.clear_cache()
    assert tune_db.load(path)["entries"] == {}
    assert any(".corrupt-" in n for n in os.listdir(tune_dir))


def test_db_transient_fault_preserves_file(tune_dir):
    """Injected read faults that exhaust retries must NOT quarantine a good
    file — the next clean load sees the original data."""
    tune_db.put_entry("keep-me", {"chosen": {}})
    path = tune_db.db_path()
    tune_db.clear_cache()
    chaos.inject("tune.db_load", times=tune_db.IO_POLICY.max_attempts)
    assert tune_db.load(path)["entries"] == {}  # served empty this call
    assert not any(".corrupt-" in n for n in os.listdir(tune_dir))
    tune_db.clear_cache()
    assert "keep-me" in tune_db.load(path)["entries"]


def test_db_save_fault_retried(tune_dir):
    chaos.inject("tune.db_save")  # one fault < retry budget
    tune_db.put_entry("retried", {"chosen": {}})
    tune_db.clear_cache()
    assert "retried" in tune_db.load(tune_db.db_path())["entries"]


# ----------------------------- checkpoints ----------------------------- #

def _tree():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.float32(1.5)}


def test_checkpoint_roundtrip_with_checksums(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    with open(os.path.join(d, "step_00000001", "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest["checksums"]) == 2
    restored, step, _ = ckpt.restore(d, _tree())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), _tree()["w"])


def test_torn_checkpoint_falls_back(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    ckpt.save(d, 2, _tree())
    # tear the newest step's arrays mid-file
    with open(os.path.join(d, "step_00000002", "arrays.npz"), "r+b") as f:
        f.seek(64)
        f.write(b"\xde\xad\xbe\xef" * 4)
    assert ckpt.latest_step(d) == 1
    _, step, _ = ckpt.restore(d, _tree())
    assert step == 1
    with pytest.raises(ckpt.CheckpointError):  # explicit bad step raises
        ckpt.restore(d, _tree(), step=2)


def test_checksum_flip_detected(tmp_path):
    """A checkpoint whose npz is loadable but whose bytes changed (bit rot)
    fails the per-leaf crc and is skipped."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    ckpt.save(d, 2, _tree())
    step2 = os.path.join(d, "step_00000002", "arrays.npz")
    with np.load(step2) as data:
        arrays = {k: data[k].copy() for k in data.files}
    arrays["leaf_0"] = arrays["leaf_0"] + 1  # silent corruption
    np.savez(step2, **arrays)
    assert ckpt._validate_step(d, 2) == "checksum"
    assert ckpt.latest_step(d) == 1


def test_partial_step_skipped(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000005"))  # torn: no files inside
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("5")
    before = _obs.counter("ckpt.skipped").value(reason="partial") or 0
    assert ckpt.latest_step(d) == 1
    assert _obs.counter("ckpt.skipped").value(reason="partial") == before + 1
    assert ckpt.valid_steps(d) == [1]


def test_checkpoint_save_retried_under_fault(tmp_path):
    d = str(tmp_path)
    chaos.inject("ckpt.save")  # one fault < retry budget
    ckpt.save(d, 3, _tree())
    assert ckpt.latest_step(d) == 3
    chaos.inject("ckpt.restore")
    _, step, _ = ckpt.restore(d, _tree())
    assert step == 3


def test_manager_surfaces_async_error(tmp_path):
    d = str(tmp_path)
    mgr = ckpt.CheckpointManager(d, async_write=True)
    # exhaust the save retry budget on the writer thread
    chaos.inject("ckpt.save", times=ckpt.IO_POLICY.max_attempts)
    before = _obs.counter("ckpt.async_errors").value(error="ChaosError") or 0
    mgr.save(1, _tree())
    with pytest.raises(ChaosError):
        mgr.wait()
    assert _obs.counter("ckpt.async_errors").value(
        error="ChaosError") == before + 1
    # the manager recovers: the next save works and wait() is clean
    mgr.save(2, _tree())
    mgr.wait()
    assert ckpt.latest_step(d) == 2


# ----------------------------- serving -------------------------------- #

def test_serve_batch_step_retried():
    from repro.launch.serve import _resilient_step

    chaos.inject("serve.batch")
    assert _resilient_step(lambda a, b: a + b, 20, 22) == 42


# ------------------------- graph validation ---------------------------- #

def test_validate_graph_accepts_valid():
    g = small_graph(seed=17)
    assert validate_graph(g, "cheap") is g
    assert g.validate("full") is g
    from_edges(4, [0, 1], [1, 2], validate="full")


def test_validate_graph_rejects_each_invariant():
    g = small_graph(seed=18)
    cases = {
        "rowptr_shape": Graph(g.n, g.rowptr[:-1], g.colidx),
        "rowptr_origin": Graph(
            g.n, np.concatenate([[1], g.rowptr[1:]]), g.colidx),
        "rowptr_total": Graph(
            g.n, np.concatenate([g.rowptr[:-1], [g.m + 3]]), g.colidx),
        "colidx_range": Graph(
            g.n, g.rowptr, np.full_like(g.colidx, g.n)),
        "vals_length": Graph(g.n, g.rowptr, g.colidx,
                             vals=np.ones(g.m + 1, np.float32)),
    }
    bad_mono = g.rowptr.copy()
    bad_mono[2] = bad_mono[1] - 1
    bad_mono[-1] = g.m  # keep the total right so monotonicity is what trips
    cases["rowptr_monotone"] = Graph(g.n, bad_mono, g.colidx)
    for check, bad in cases.items():
        with pytest.raises(GraphValidationError) as ei:
            validate_graph(bad, "full")
        assert ei.value.check == check, (check, ei.value.check)


def test_from_edges_validates_coo():
    with pytest.raises(GraphValidationError) as ei:
        from_edges(4, [0, 9], [1, 2], validate="cheap")
    assert ei.value.check == "coo_range"


def test_build_blocked_validates():
    g = small_graph(seed=19)
    bad = Graph(g.n, g.rowptr, np.full_like(g.colidx, g.n))
    with pytest.raises(GraphValidationError):
        build_blocked(bad, block_size=32, validate="full")
    build_blocked(g, block_size=32, validate="cheap")  # valid passes


# ------------------ property test: CSR mutations caught ------------------ #
# hypothesis is an optional dev dependency; only this test skips without it.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @st.composite
    def mutated_csr(draw):
        n = draw(st.integers(4, 64))
        m = draw(st.integers(1, 200))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        keep = src != dst
        if not keep.any():
            src, dst = np.array([0]), np.array([1])
        else:
            src, dst = src[keep], dst[keep]
        g = from_edges(n, src, dst, dedup=True)
        mutation = draw(st.sampled_from(
            ["rowptr_shape", "rowptr_origin", "rowptr_total",
             "rowptr_monotone", "colidx_range", "vals_length"]))
        rowptr, colidx, vals = g.rowptr.copy(), g.colidx.copy(), None
        if mutation == "rowptr_shape":
            rowptr = rowptr[:-1]
        elif mutation == "rowptr_origin":
            rowptr[0] = draw(st.integers(1, 5))
        elif mutation == "rowptr_total":
            rowptr[-1] = g.m + draw(st.integers(1, 9))
        elif mutation == "rowptr_monotone":
            i = draw(st.integers(1, n - 1))
            rowptr[i] = -1  # below rowptr[i-1] >= 0 and non-monotone
        elif mutation == "colidx_range":
            i = draw(st.integers(0, g.m - 1))
            colidx[i] = draw(st.sampled_from([-1, n, n + 7]))
        elif mutation == "vals_length":
            vals = np.ones(g.m + draw(st.integers(1, 4)), np.float32)
        return Graph(g.n, rowptr, colidx, vals=vals), mutation

    @given(mutated_csr())
    @settings(max_examples=40, deadline=None)
    def test_csr_mutation_always_caught(case):
        """∀ invariant-violating CSR mutation: full validation raises a
        structured GraphValidationError."""
        bad, mutation = case
        with pytest.raises(GraphValidationError):
            validate_graph(bad, "full")
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_csr_mutation_always_caught():
        pass

"""Hypothesis property tests: fused TOCAB ≡ slab TOCAB, bit for bit.

The fused pipeline is a pure execution transform — for every graph, block
size, direction, and semiring, ``impl="fused"`` must return the slab
engines' exact bits (identical per-destination operand order).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_blocked, from_edges, tocab_edge_reduce, tocab_pull, tocab_push,
)


@st.composite
def random_graph(draw):
    n = draw(st.integers(4, 200))
    m = draw(st.integers(1, 600))
    seed = draw(st.integers(0, 2**31 - 1))
    weighted = draw(st.booleans())
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        src, dst = np.array([0]), np.array([min(1, n - 1)])
    else:
        src, dst = src[keep], dst[keep]
    vals = rng.random(len(src), dtype=np.float32) if weighted else None
    return from_edges(n, src, dst, vals=vals, dedup=True)


BLOCKS = st.sampled_from([4, 16, 64])
REDUCES = st.sampled_from(["sum", "min", "max"])


@given(random_graph(), BLOCKS, REDUCES, st.booleans())
@settings(max_examples=25, deadline=None)
def test_fused_pull_bitwise(g, block_size, reduce, matrix):
    bg = build_blocked(g, block_size=block_size)
    rng = np.random.default_rng(0)
    shape = (g.n, 2) if matrix else (g.n,)
    x = jnp.asarray(rng.random(shape).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(tocab_pull(bg, x, reduce=reduce, impl="fused")),
        np.asarray(tocab_pull(bg, x, reduce=reduce)))


@given(random_graph(), st.sampled_from([8, 32]), REDUCES)
@settings(max_examples=15, deadline=None)
def test_fused_push_bitwise(g, block_size, reduce):
    bg = build_blocked(g, block_size=block_size, direction="push")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    np.testing.assert_array_equal(
        np.asarray(tocab_push(bg, x, reduce=reduce, impl="fused")),
        np.asarray(tocab_push(bg, x, reduce=reduce)))


@given(random_graph(), st.sampled_from(["pull", "push"]))
@settings(max_examples=15, deadline=None)
def test_fused_edge_reduce_bitwise(g, direction):
    bg = build_blocked(g, block_size=16, direction=direction)
    rng = np.random.default_rng(2)
    ev = jnp.asarray(rng.random(g.m, dtype=np.float32))
    np.testing.assert_array_equal(
        np.asarray(tocab_edge_reduce(bg, ev, impl="fused")),
        np.asarray(tocab_edge_reduce(bg, ev)))


@given(random_graph(), BLOCKS,
       st.floats(0.1, 1.0), st.floats(-1.0, 1.0))
@settings(max_examples=15, deadline=None)
def test_fused_epilogue_bitwise(g, block_size, mul, add):
    """The fused kernel's baked-in affine apply == the slab path's
    trailing ``out*mul + add`` pass."""
    bg = build_blocked(g, block_size=block_size)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    eps = (np.float32(mul), np.float32(add))
    np.testing.assert_array_equal(
        np.asarray(tocab_pull(bg, x, epilogue=eps, impl="fused")),
        np.asarray(tocab_pull(bg, x, epilogue=eps)))

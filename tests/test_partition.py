"""TOCAB partitioning invariants (DESIGN.md §7, items 1-2)."""
import numpy as np
import pytest

from repro.core import build_blocked, rmat_graph, uniform_random_graph


@pytest.mark.parametrize("direction", ["pull", "push"])
@pytest.mark.parametrize("block_size", [32, 128, 1024])
def test_edge_conservation(direction, block_size):
    g = rmat_graph(scale=9, edge_factor=8, seed=3)
    bg = build_blocked(g, block_size=block_size, direction=direction)
    # every original edge appears exactly once across subgraph slabs
    mask = np.asarray(bg.edge_mask)
    perm = np.asarray(bg.edge_perm)[mask]
    assert perm.shape[0] == g.m
    assert np.array_equal(np.sort(perm), np.arange(g.m))
    assert int(np.asarray(bg.n_edges).sum()) == g.m


def test_window_confinement():
    """Gather side of each block stays within [b·B, (b+1)·B) — the cache
    window guarantee that makes the scheme work."""
    g = rmat_graph(scale=8, edge_factor=8, seed=1)
    bg = build_blocked(g, block_size=64)
    widx = np.asarray(bg.window_idx)
    mask = np.asarray(bg.edge_mask)
    assert widx[mask].min() >= 0
    assert widx[mask].max() < bg.block_size


def test_local_id_bijection():
    g = rmat_graph(scale=8, edge_factor=8, seed=2)
    bg = build_blocked(g, block_size=64)
    src, dst = g.edges()
    idmap = np.asarray(bg.id_map)
    cidx = np.asarray(bg.compact_idx)
    mask = np.asarray(bg.edge_mask)
    nloc = np.asarray(bg.n_local)
    for b in range(bg.num_blocks):
        em = mask[b]
        if not em.any():
            continue
        locals_used = np.unique(cidx[b][em])
        # dense: 0..n_local-1, no gaps
        assert np.array_equal(locals_used, np.arange(nloc[b]))
        # id_map maps each local to the correct global dst
        globals_mapped = idmap[b][cidx[b][em]]
        lo, hi = b * bg.block_size, (b + 1) * bg.block_size
        orig = np.asarray(bg.edge_perm)[b][em]
        assert np.array_equal(globals_mapped, dst[orig])
        assert (src[orig] >= lo).all() and (src[orig] < hi).all()
        # padded id_map slots point at the drop segment n
        assert (idmap[b][nloc[b]:] == g.n).all()


def test_subgraph_degree_drop():
    """Paper Table 1: average degree inside subgraphs falls vs the original
    graph (the reason VWC loses SIMD efficiency after blocking)."""
    g = rmat_graph(scale=12, edge_factor=12, seed=5)
    bg = build_blocked(g, block_size=256)
    per_block_nloc = np.asarray(bg.n_local).astype(np.float64)
    per_block_edges = np.asarray(bg.n_edges).astype(np.float64)
    sub_deg = per_block_edges.sum() / per_block_nloc.sum()
    assert sub_deg < g.m / g.n  # strictly lower average degree


def test_block_count_scaling():
    g = uniform_random_graph(4096, 32768, seed=0)
    small = build_blocked(g, block_size=128)
    large = build_blocked(g, block_size=1024)
    assert small.num_blocks == 32 and large.num_blocks == 4
    # paper Table 4: L2/VMEM-sized blocks → far fewer partitions


def test_choose_block_size_vmem_budget():
    from repro.core import choose_block_size
    bs = choose_block_size(10**7, fast_mem_bytes=4 * 1024 * 1024)
    assert bs * 4 <= 4 * 1024 * 1024
    assert bs % 128 == 0

"""Hypothesis property tests over the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    DeviceGraph, baseline_pull, build_blocked, from_edges, tocab_pull,
    tocab_push,
)


@st.composite
def random_graph(draw):
    n = draw(st.integers(4, 200))
    m = draw(st.integers(1, 600))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        src, dst = np.array([0]), np.array([min(1, n - 1)])
    else:
        src, dst = src[keep], dst[keep]
    vals = rng.random(len(src), dtype=np.float32)
    return from_edges(n, src, dst, vals=vals, dedup=True)


@given(random_graph(), st.sampled_from([4, 16, 64]))
@settings(max_examples=25, deadline=None)
def test_tocab_equals_baseline(g, block_size):
    """Core invariant: blocking + compaction never changes the result."""
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=block_size)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(tocab_pull(bg, x)), np.asarray(baseline_pull(dg, x)),
        rtol=1e-4, atol=1e-5)


@given(random_graph(), st.sampled_from([8, 32]))
@settings(max_examples=25, deadline=None)
def test_partition_conservation(g, block_size):
    bg = build_blocked(g, block_size=block_size)
    mask = np.asarray(bg.edge_mask)
    perm = np.asarray(bg.edge_perm)[mask]
    assert np.array_equal(np.sort(perm), np.arange(g.m))
    # compaction: every local id < n_local of its block
    cidx = np.asarray(bg.compact_idx)
    nloc = np.asarray(bg.n_local)
    for b in range(bg.num_blocks):
        if mask[b].any():
            assert cidx[b][mask[b]].max() < nloc[b]


@given(random_graph())
@settings(max_examples=15, deadline=None)
def test_push_pull_duality(g):
    """push on G == pull on G (same math, different dataflow)."""
    dg = DeviceGraph.from_host(g)
    bgp = build_blocked(g, block_size=32, direction="push")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(tocab_push(bgp, x)), np.asarray(baseline_pull(dg, x)),
        rtol=1e-4, atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64]))
@settings(max_examples=10, deadline=None)
def test_pagerank_mass_conservation(seed, block_size):
    """PR with dangling redistribution conserves probability mass."""
    from repro.core import pagerank, rmat_graph
    g = rmat_graph(scale=6, edge_factor=4, seed=seed % 1000)
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=block_size)
    r, _ = pagerank(dg, bg, variant="gc-pull", tol=1e-9)
    assert float(jnp.sum(r)) == pytest.approx(1.0, abs=1e-4)
    assert float(jnp.min(r)) > 0

"""Optimizers, checkpointing, trainer loop, fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.optim import (
    adafactor, adamw, apply_updates, clip_by_global_norm, constant_schedule,
    cosine_schedule, global_norm, sgd,
)
from repro.train.trainer import StragglerWatchdog, Trainer, make_train_step


def _quadratic(params, batch):
    loss = sum(jnp.sum((p - 3.0) ** 2) for p in jax.tree.leaves(params))
    return loss, {}


@pytest.mark.parametrize("opt_name", ["adamw", "sgd", "adafactor"])
def test_optimizers_minimize_quadratic(opt_name):
    opt = {
        "adamw": adamw(constant_schedule(0.1)),
        "sgd": sgd(constant_schedule(0.05), momentum=0.5),
        "adafactor": adafactor(constant_schedule(0.5)),
    }[opt_name]
    params = {"a": jnp.zeros((4, 4)), "b": jnp.ones((3,))}
    state = opt.init(params)
    for _ in range(120):
        grads = jax.grad(lambda p: _quadratic(p, None)[0])(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    loss, _ = _quadratic(params, None)
    assert float(loss) < 1e-2


def test_clip_by_global_norm():
    clip = clip_by_global_norm(1.0)
    g = {"w": jnp.full((10,), 100.0)}
    u, _ = clip.update(g, clip.init(g), None)
    assert float(global_norm(u)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    f = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert float(f(jnp.int32(10))) == pytest.approx(1e-3)
    assert float(f(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-3)


def test_checkpoint_roundtrip_bitwise():
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "s": jnp.int32(7),
            "nested": {"x": jnp.ones((2,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, tree, extra={"note": "hi"})
        restored, step, extra = ckpt.restore(d, tree)
        assert step == 5 and extra["note"] == "hi"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_keep_k_and_async():
    tree = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt.CheckpointManager(d, keep=2, async_write=True)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        mgr.wait()
        steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]
        assert ckpt.latest_step(d) == 4


def test_checkpoint_crash_safety():
    """A leftover .tmp dir must not break restore (atomic rename)."""
    tree = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        os.makedirs(os.path.join(d, "step_00000002.tmp"))  # simulated crash
        restored, step, _ = ckpt.restore(d, tree)
        assert step == 1


def test_grad_accum_equals_big_batch():
    """Microbatch accumulation == full-batch gradient (linear loss)."""
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.random((4, 1), dtype=np.float32))}
    X = jnp.asarray(rng.random((8, 4), dtype=np.float32))
    Y = jnp.asarray(rng.random((8, 1), dtype=np.float32))
    opt = sgd(constant_schedule(0.1), momentum=0.0)
    s1 = make_train_step(loss_fn, opt)
    s2 = make_train_step(loss_fn, opt, grad_accum=2)
    p1, _, m1 = s1(params, opt.init(params), {"x": X, "y": Y})
    batch2 = {"x": X.reshape(2, 4, 4), "y": Y.reshape(2, 4, 1)}
    p2, _, m2 = s2(params, opt.init(params), batch2)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5)


def test_straggler_watchdog_flags_outlier():
    wd = StragglerWatchdog(threshold_sigma=3.0, warmup=3)
    for i in range(20):
        wd.observe(i, 0.1 + 0.001 * (i % 3))
    assert not wd.flagged
    assert wd.observe(20, 5.0)  # 50× step time → flagged
    assert wd.flagged[-1][0] == 20


def test_preemption_restart_exact_resume():
    """Kill-and-resume must continue bit-exact from the checkpoint."""
    def loss_fn(params, batch):
        return jnp.sum(params["w"] ** 2) * batch["s"], {}

    params = {"w": jnp.ones((3,))}
    opt = adamw(constant_schedule(0.01))

    def batches():
        i = 0
        while True:
            yield {"s": jnp.float32(1.0 + (i % 3))}
            i += 1

    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(loss_fn=loss_fn, optimizer=opt, ckpt_dir=d, ckpt_every=5,
                     donate=False)
        p, s = tr.init_state(params)
        p1, s1, _ = tr.run(p, s, batches(), num_steps=10, log_every=100,
                           log_fn=lambda *_: None)
        # "preempted" new process: fresh trainer, restore, run remaining
        tr2 = Trainer(loss_fn=loss_fn, optimizer=opt, ckpt_dir=d,
                      ckpt_every=5, donate=False)
        p2, s2, step = tr2.maybe_restore(p, s)
        assert step == 10
        gen = batches()
        for _ in range(step):  # deterministic stream replay
            next(gen)
        p3, s3, _ = tr2.run(p2, s2, gen, start_step=step, num_steps=12,
                            log_every=100, log_fn=lambda *_: None)
        # continue original for 2 more steps → must match
        gen2 = batches()
        for _ in range(10):
            next(gen2)
        p4, s4, _ = tr.run(p1, s1, gen2, start_step=10, num_steps=12,
                           log_every=100, log_fn=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(p3["w"]), np.asarray(p4["w"]))

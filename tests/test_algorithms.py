"""PageRank / BFS / BC / SSSP correctness vs networkx (§7 items 3-4)."""
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.core import (
    DeviceGraph, bc, bfs, build_blocked, pagerank, rmat_graph, spmv, sssp,
    to_networkx, INF_DEPTH,
)


@pytest.fixture(scope="module")
def small():
    g = rmat_graph(scale=8, edge_factor=6, seed=11, weights=True)
    return g, DeviceGraph.from_host(g), build_blocked(g, block_size=64), to_networkx(g)


@pytest.fixture(scope="module")
def small_unweighted():
    """PR is unweighted in the paper; networkx.pagerank is weight-sensitive."""
    from repro.core.graph import Graph
    g = rmat_graph(scale=8, edge_factor=6, seed=11, weights=True)
    gu = Graph(g.n, g.rowptr, g.colidx, None)
    return gu, DeviceGraph.from_host(gu), build_blocked(gu, block_size=64), \
        to_networkx(gu)


def test_pagerank_vs_networkx(small_unweighted):
    g, dg, bg, G = small_unweighted
    r, iters = pagerank(dg, bg, variant="gc-pull", tol=1e-10)
    ref = nx.pagerank(G, alpha=0.85, tol=1e-12, max_iter=1000)
    ref = np.array([ref[i] for i in range(g.n)])
    np.testing.assert_allclose(np.asarray(r), ref, atol=1e-6)
    assert 5 < int(iters) < 200


@pytest.mark.parametrize("variant", ["base", "push", "cb", "gc-pull", "gc-push"])
def test_pagerank_variants_agree(small_unweighted, variant):
    g, dg, bg, G = small_unweighted
    bgv = (build_blocked(g, block_size=64, direction="push")
           if variant == "gc-push" else bg)
    r, _ = pagerank(dg, bgv, variant=variant, tol=1e-10)
    r0, _ = pagerank(dg, bg, variant="base", tol=1e-10)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r0), atol=1e-7)


def test_spmv_matches_dense(small):
    g, dg, bg, G = small
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    A = np.zeros((g.n, g.n), np.float32)
    src, dst = g.edges()
    A[dst, src] = g.vals  # y[dst] = Σ A[dst,src] x[src]
    ref = A @ np.asarray(x)
    for variant in ("base", "gc-pull"):
        y = spmv(dg, bg, x, variant=variant)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)


def test_bfs_vs_networkx(small):
    g, dg, bg, G = small
    depth, levels, n_push, n_pull = bfs(dg, bg, jnp.int32(5))
    ref = nx.single_source_shortest_path_length(G, 5)
    d = np.asarray(depth)
    for v, l in ref.items():
        assert d[v] == l
    unreached = set(range(g.n)) - set(ref)
    assert all(d[v] >= INF_DEPTH for v in unreached)
    assert int(n_push) + int(n_pull) == int(levels)
    assert int(n_pull) >= 1  # direction optimization actually engaged


def test_sssp_vs_networkx(small):
    g, dg, bg, G = small
    dist, _ = sssp(dg, bg, jnp.int32(5))
    ref = nx.single_source_dijkstra_path_length(G, 5, weight="weight")
    dd = np.asarray(dist)
    for v, l in ref.items():
        assert dd[v] == pytest.approx(l, rel=1e-5)
    assert all(np.isinf(dd[v]) for v in range(g.n) if v not in ref)


def test_bc_vs_networkx():
    g = rmat_graph(scale=6, edge_factor=4, seed=13)
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=16)
    G = to_networkx(g)
    total = np.zeros(g.n, np.float64)
    for s in range(g.n):
        scores, _, _ = bc(dg, bg, jnp.int32(s))
        total += np.asarray(scores, np.float64)
    ref = nx.betweenness_centrality(G, normalized=False)
    ref = np.array([ref[i] for i in range(g.n)])
    np.testing.assert_allclose(total, ref, rtol=1e-3, atol=1e-3)


def test_bfs_blocked_equals_flat(small):
    g, dg, bg, _ = small
    d1, *_ = bfs(dg, bg, jnp.int32(0))
    d2, *_ = bfs(dg, None, jnp.int32(0))
    assert (np.asarray(d1) == np.asarray(d2)).all()


def test_connected_components_vs_networkx():
    from repro.core import connected_components
    g = rmat_graph(scale=8, edge_factor=2, seed=21)
    dg = DeviceGraph.from_host(g)
    dgt = DeviceGraph.from_host(g.transpose())
    bg = build_blocked(g, block_size=64)
    labels, iters = connected_components(dg, dgt, bg)
    import networkx as nx
    G = to_networkx(g).to_undirected()
    comps = list(nx.connected_components(G))
    lab = np.asarray(labels)
    # same partition: every nx component maps to exactly one label
    seen = set()
    for comp in comps:
        ls = {int(lab[v]) for v in comp}
        assert len(ls) == 1, f"component split: {ls}"
        seen |= ls
    assert len(seen) == len(comps)  # and labels don't merge components

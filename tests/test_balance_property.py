"""Hypothesis property tests: sparsity-aware scheduling ≡ uniform TOCAB.

The load balancer must be a pure performance transform — for every graph,
block size, and threshold placement (including degenerate single-bin
splits), the balanced engines return the uniform engines' results.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    DeviceGraph, baseline_pull, build_blocked, from_edges, make_schedule,
    tocab_edge_reduce, tocab_pull, tocab_push,
)

INF = float("inf")

# Spread thresholds across every bin-boundary regime: all-sparse, all-dense,
# all-medium, data-driven terciles, and the physical default.
THRESHOLDS = st.sampled_from(
    [(INF, INF), (0.0, 0.0), (0.0, INF), "auto", (4.0, 32.0), (1.0, 8.0)])


@st.composite
def random_graph(draw):
    n = draw(st.integers(4, 200))
    m = draw(st.integers(1, 600))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        src, dst = np.array([0]), np.array([min(1, n - 1)])
    else:
        src, dst = src[keep], dst[keep]
    vals = rng.random(len(src), dtype=np.float32)
    return from_edges(n, src, dst, vals=vals, dedup=True)


@given(random_graph(), st.sampled_from([4, 16, 64]), THRESHOLDS)
@settings(max_examples=25, deadline=None)
def test_balanced_pull_equals_uniform(g, block_size, thresholds):
    bg = build_blocked(g, block_size=block_size, bin_thresholds=thresholds)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(tocab_pull(bg, x, schedule="balanced")),
        np.asarray(tocab_pull(bg, x)),
        rtol=1e-4, atol=1e-5)


@given(random_graph(), st.sampled_from([8, 32]), THRESHOLDS)
@settings(max_examples=15, deadline=None)
def test_balanced_push_equals_baseline(g, block_size, thresholds):
    dg = DeviceGraph.from_host(g)
    bgp = build_blocked(g, block_size=block_size, direction="push",
                        bin_thresholds=thresholds)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(tocab_push(bgp, x, schedule="balanced")),
        np.asarray(baseline_pull(dg, x)),
        rtol=1e-4, atol=1e-5)


@given(random_graph(), st.sampled_from([8, 32]),
       st.sampled_from(["pull", "push"]), THRESHOLDS)
@settings(max_examples=20, deadline=None)
def test_balanced_edge_reduce_equals_uniform(g, block_size, direction,
                                             thresholds):
    """Both layouts: push compacts the *source* side, whose per-block row
    counts can exceed the window-side classification rows (hub dsts) — the
    balanced slab must be sized by the compact budget."""
    bg = build_blocked(g, block_size=block_size, direction=direction,
                       bin_thresholds=thresholds)
    rng = np.random.default_rng(3)
    ev = jnp.asarray(rng.random(g.m, dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(tocab_edge_reduce(bg, ev, schedule="balanced")),
        np.asarray(tocab_edge_reduce(bg, ev)),
        rtol=1e-4, atol=1e-5)


@given(random_graph(), st.sampled_from(["min", "max"]))
@settings(max_examples=15, deadline=None)
def test_balanced_pull_nonsum_reduce(g, reduce):
    """min/max ride the sparse/scan strategies (dense bin falls back)."""
    bg = build_blocked(g, block_size=16, bin_thresholds=(1.0, 4.0))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.random(g.n, dtype=np.float32))
    ref = np.asarray(tocab_pull(bg, x, reduce=reduce))
    out = np.asarray(tocab_pull(bg, x, reduce=reduce, schedule="balanced"))
    f = np.isfinite(ref)
    assert (np.isfinite(out) == f).all()
    np.testing.assert_allclose(out[f], ref[f], rtol=1e-4, atol=1e-5)


@given(st.lists(st.integers(0, 500), min_size=1, max_size=40), THRESHOLDS)
@settings(max_examples=50, deadline=None)
def test_schedule_partitions_blocks(edges, thresholds):
    """make_schedule is total: every block lands in exactly one bin and the
    per-bin aggregates tally, for any edge histogram and threshold mode."""
    rows = [max(1, e // 3) for e in edges]
    compact = [max(1, e // 2) for e in edges]  # push-like: ≠ classification rows
    sched = make_schedule(edges, rows, thresholds=thresholds,
                          n_compact_rows=compact)
    assert sum(sched.blocks_per_bin) == len(edges)
    assert sum(sched.edges_per_bin) == sum(edges)
    assert sum(sched.rows_per_bin) == sum(rows)
    for bin_id in range(3):
        ids = sched.blocks_in(bin_id)
        assert len(ids) == sched.blocks_per_bin[bin_id]
        rb = sched.row_budget_per_bin[bin_id]
        cb = sched.compact_budget_per_bin[bin_id]
        assert rb % 8 == 0 and cb % 8 == 0
        assert all(rows[i] <= rb for i in ids)
        assert all(compact[i] <= cb for i in ids)
    hash(sched)

"""Fused TOCAB pipeline: bit-equivalence with the slab engines.

The fused path (``impl="fused"``) keeps the per-block partial accumulator
resident and fuses the per-vertex apply epilogue — it is a pure execution
transform, so every engine call must return the *exact* bits of the slab
path (same per-destination operand order).  Full algorithm loops
(``pagerank``'s ``while_loop``) are compared with a tight ``allclose``
instead: XLA compiles the identical program differently inside a
``while_loop`` body, which perturbs even slab-vs-slab at ~1e-9.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeviceGraph, build_blocked, from_edges, pagerank,
    pagerank_iteration, rmat_graph, spmv, tocab_edge_reduce, tocab_pull,
    tocab_push,
)
from repro.core.traversal import bfs, sssp
from repro.resilience import chaos

# Engine-identity tests (HLO shapes, fused obs counters) assert *which*
# engine ran; under chaos-smoke the ladder may legitimately degrade fused
# dispatch, so they skip when that site is armed.
_chaos_on_fused = pytest.mark.skipif(
    chaos.active_for("kernel.tocab_fused"),
    reason="chaos can degrade fused dispatch to slab — engine-identity "
           "assertions don't hold under fault injection")


@pytest.fixture(scope="module")
def setup():
    g = rmat_graph(scale=9, edge_factor=8, seed=7, weights=True)
    dg = DeviceGraph.from_host(g)
    bg = build_blocked(g, block_size=128, direction="pull")
    bgp = build_blocked(g, block_size=128, direction="push")
    return g, dg, bg, bgp


def _vals(n, d=None, seed=0):
    rng = np.random.default_rng(seed)
    shape = (n,) if d is None else (n, d)
    return jnp.asarray(rng.random(shape).astype(np.float32))


def hub_graph(n=256):
    """Everything points at a few hubs — extreme compaction ratio."""
    src = np.concatenate([np.arange(1, n), np.arange(n)])
    dst = np.concatenate([np.zeros(n - 1, np.int64), (np.arange(n) + 1) % n])
    keep = src != dst
    rng = np.random.default_rng(4)
    vals = rng.random(int(keep.sum()), dtype=np.float32)
    return from_edges(n, src[keep], dst[keep], vals=vals, dedup=True)


def balmix_graph(n=2048, deg=8, seed=0):
    """Mixed-density graph (dense/medium/sparse bins by construction) —
    small-scale twin of ``benchmarks.common.balance_mix_graph``."""
    rng = np.random.default_rng(seed)
    q = n // 4
    srcs, dsts = [], []
    for lo, hi, pool in ((0, q, 16), (q, 2 * q, 256), (2 * q, n, n)):
        src = np.repeat(np.arange(lo, hi), deg)
        dst = rng.integers(0, pool, src.shape[0])
        srcs.append(src)
        dsts.append(dst)
    src, dst = np.concatenate(srcs), np.concatenate(dsts)
    keep = src != dst
    vals = rng.random(int(keep.sum()), dtype=np.float32)
    return from_edges(n, src[keep], dst[keep], vals=vals, dedup=True)


# --------------------------------------------------------------------- #
# engine-level bit-identity
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("reduce", ["sum", "min", "max"])
@pytest.mark.parametrize("d", [None, 3])
def test_fused_pull_bitwise(setup, reduce, d):
    g, dg, bg, _ = setup
    x = _vals(g.n, d)
    np.testing.assert_array_equal(
        np.asarray(tocab_pull(bg, x, reduce=reduce, impl="fused")),
        np.asarray(tocab_pull(bg, x, reduce=reduce)))


@pytest.mark.parametrize("reduce", ["sum", "min", "max"])
@pytest.mark.parametrize("d", [None, 3])
def test_fused_push_bitwise(setup, reduce, d):
    g, dg, _, bgp = setup
    x = _vals(g.n, d, seed=1)
    np.testing.assert_array_equal(
        np.asarray(tocab_push(bgp, x, reduce=reduce, impl="fused")),
        np.asarray(tocab_push(bgp, x, reduce=reduce)))


def test_fused_combine_semiring(setup):
    g, dg, bg, bgp = setup
    x = _vals(g.n, seed=2)
    minplus = lambda v, ev: v + ev  # noqa: E731
    for fn, b in ((tocab_pull, bg), (tocab_push, bgp)):
        np.testing.assert_array_equal(
            np.asarray(fn(b, x, reduce="min", combine=minplus, impl="fused")),
            np.asarray(fn(b, x, reduce="min", combine=minplus)))


@pytest.mark.parametrize("direction", ["pull", "push"])
def test_fused_edge_reduce_bitwise(setup, direction):
    g, dg, bg, bgp = setup
    b = bg if direction == "pull" else bgp
    ev = _vals(g.m, seed=3)
    np.testing.assert_array_equal(
        np.asarray(tocab_edge_reduce(b, ev, impl="fused")),
        np.asarray(tocab_edge_reduce(b, ev)))


def test_fused_epilogue_bitwise(setup):
    """The fused kernel's baked-in affine apply == the slab path's trailing
    pass, bit for bit — the property PageRank's iteration relies on."""
    g, dg, bg, bgp = setup
    x = _vals(g.n, seed=4)
    eps = (0.85, 0.15 / g.n)
    for fn, b in ((tocab_pull, bg), (tocab_push, bgp)):
        slab = np.asarray(fn(b, x, epilogue=eps))
        np.testing.assert_array_equal(
            np.asarray(fn(b, x, epilogue=eps, impl="fused")), slab)
        np.testing.assert_array_equal(
            slab, np.asarray(fn(b, x)) * eps[0] + eps[1])


def test_fused_epilogue_requires_sum(setup):
    g, _, bg, _ = setup
    with pytest.raises(ValueError, match="sum"):
        tocab_pull(bg, _vals(g.n), reduce="min", epilogue=(1.0, 0.0),
                   impl="fused")


@pytest.mark.parametrize("build", [hub_graph, balmix_graph],
                         ids=["hub", "balmix"])
@pytest.mark.parametrize("direction", ["pull", "push"])
def test_fused_graph_families(build, direction):
    g = build()
    b = build_blocked(g, block_size=64, direction=direction)
    fn = tocab_pull if direction == "pull" else tocab_push
    x = _vals(g.n, seed=5)
    np.testing.assert_array_equal(
        np.asarray(fn(b, x, impl="fused")), np.asarray(fn(b, x)))
    np.testing.assert_array_equal(
        np.asarray(tocab_edge_reduce(b, _vals(g.m, seed=6), impl="fused")),
        np.asarray(tocab_edge_reduce(b, _vals(g.m, seed=6))))


@pytest.mark.parametrize("direction", ["pull", "push"])
def test_fused_pallas_interpret(setup, direction):
    """The Pallas kernels (interpret mode off-TPU) agree with the slab
    engines too, scalar and (n, d)."""
    from repro.kernels.tocab_fused import fused_pull, fused_push

    g, dg, bg, bgp = setup
    b = bg if direction == "pull" else bgp
    fused = fused_pull if direction == "pull" else fused_push
    slab = tocab_pull if direction == "pull" else tocab_push
    for d in (None, 2):
        x = _vals(g.n, d, seed=7)
        np.testing.assert_array_equal(
            np.asarray(fused(b, x, backend="pallas", interpret=True)),
            np.asarray(slab(b, x)))


def test_fused_push_bin_major_order(setup):
    """Disjoint destination windows ⇒ the balance module's bin-major visit
    order (the default when a schedule is attached) is bit-identical."""
    from repro.core.balance import fused_block_order
    from repro.kernels.tocab_fused import fused_push

    g, dg, _, bgp = setup
    order = fused_block_order(bgp)
    assert sorted(order) == list(range(bgp.num_blocks))
    x = _vals(g.n, seed=8)
    ref = np.asarray(tocab_push(bgp, x))
    np.testing.assert_array_equal(
        np.asarray(fused_push(bgp, x, block_order=order)), ref)
    np.testing.assert_array_equal(
        np.asarray(fused_push(bgp, x, block_order=None)), ref)


# --------------------------------------------------------------------- #
# dispatch / reconciliation
# --------------------------------------------------------------------- #
def test_fused_balanced_conflict(setup):
    g, _, bg, _ = setup
    x = _vals(g.n)
    with pytest.raises(ValueError, match="balanced"):
        tocab_pull(bg, x, schedule="balanced", impl="fused")
    # the auto side yields instead of raising
    np.testing.assert_allclose(
        np.asarray(tocab_pull(bg, x, schedule="balanced", impl="auto")),
        np.asarray(tocab_pull(bg, x, schedule="balanced")),
        rtol=1e-6, atol=1e-7)


def test_fused_unknown_impl(setup):
    g, _, bg, _ = setup
    with pytest.raises(ValueError, match="impl"):
        tocab_pull(bg, _vals(g.n), impl="warp")


# --------------------------------------------------------------------- #
# algorithm integration
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("variant", ["gc-pull", "gc-push"])
def test_pagerank_iteration_bitwise(setup, variant):
    g, dg, bg, bgp = setup
    bgv = bgp if variant == "gc-push" else bg
    rank = jnp.full((g.n,), 1.0 / g.n, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(pagerank_iteration(variant, dg, bgv, rank, dg.out_degree,
                                      impl="fused")),
        np.asarray(pagerank_iteration(variant, dg, bgv, rank,
                                      dg.out_degree)))


@pytest.mark.parametrize("variant", ["gc-pull", "gc-push"])
def test_pagerank_fused(setup, variant):
    # while_loop bodies compile with different fusion choices than the same
    # program standalone (slab-vs-slab drifts ~1e-9 too) → allclose here.
    g, dg, bg, bgp = setup
    bgv = bgp if variant == "gc-push" else bg
    r_f, it_f = pagerank(dg, bgv, variant=variant, impl="fused", tol=1e-8)
    r_s, it_s = pagerank(dg, bgv, variant=variant, tol=1e-8)
    np.testing.assert_allclose(np.asarray(r_f), np.asarray(r_s),
                               rtol=1e-6, atol=1e-8)
    assert int(it_f) < 200 and int(it_s) < 200  # both converged


@pytest.mark.parametrize("variant", ["gc-pull", "gc-push"])
def test_spmv_fused_bitwise(setup, variant):
    g, dg, bg, bgp = setup
    bgv = bgp if variant == "gc-push" else bg
    x = _vals(g.n, seed=9)
    np.testing.assert_array_equal(
        np.asarray(spmv(dg, bgv, x, variant=variant, impl="fused")),
        np.asarray(spmv(dg, bgv, x, variant=variant)))
    np.testing.assert_array_equal(
        np.asarray(spmv(dg, bgv, x, variant=variant, impl="fused",
                        scale=2.5)),
        np.asarray(spmv(dg, bgv, x, variant=variant, scale=2.5)))


def test_traversal_fused(setup):
    g, dg, bg, _ = setup
    d_f, *_ = bfs(dg, bg, jnp.int32(0), impl="fused")
    d_s, *_ = bfs(dg, bg, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(d_f), np.asarray(d_s))
    dist_f, _ = sssp(dg, bg, jnp.int32(0), impl="fused")
    dist_s, _ = sssp(dg, bg, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(dist_f), np.asarray(dist_s))


# --------------------------------------------------------------------- #
# the point of the exercise: no partial slab in HBM
# --------------------------------------------------------------------- #
@_chaos_on_fused
def test_fused_lowering_has_no_partial_slab(setup):
    """The compiled fused program must not allocate the
    ``(num_blocks, local_budget)`` partial buffer the slab path round-trips
    (asserted on the optimized HLO)."""
    g, dg, _, _ = setup
    bg = build_blocked(g, block_size=64, direction="pull")
    nb, lb = bg.num_blocks, bg.local_budget
    # the slab sizes must not collide with the edge slab's, or the shape
    # strings below can't discriminate the two buffers
    assert nb * lb != bg.edge_budget
    x = _vals(g.n, d=3)
    slab_shapes = (f"f32[{nb},{lb},3]", f"f32[{nb * lb},3]")

    slab_hlo = jax.jit(lambda v: tocab_pull(bg, v)).lower(x) \
        .compile().as_text()
    assert any(s in slab_hlo for s in slab_shapes), \
        "sanity: slab lowering should materialize the partial slab"

    fused_hlo = jax.jit(lambda v: tocab_pull(bg, v, impl="fused")) \
        .lower(x).compile().as_text()
    for s in slab_shapes:
        assert s not in fused_hlo, f"fused lowering materializes {s}"


@_chaos_on_fused
def test_fused_obs_counters(setup):
    from repro.obs.metrics import registry as _obs

    g, dg, bg, _ = setup
    blocks = _obs.counter("tocab.fused_blocks")
    saved = _obs.counter("tocab.partial_hbm_bytes_saved")
    labels = dict(engine="fused_pull", direction="pull")
    b0 = blocks.value(**labels) or 0
    s0 = saved.value(**labels) or 0
    tocab_pull(bg, _vals(g.n), impl="fused")
    assert blocks.value(**labels) == b0 + bg.num_blocks
    assert saved.value(**labels) == s0 + bg.num_blocks * bg.local_budget * 4


# --------------------------------------------------------------------- #
# ragged edge budgets (tocab_spmm regression)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("chunk", [7, 100, 999999])
def test_spmm_ragged_chunk(setup, chunk):
    """The tile kernel used to require ``edge_budget % chunk == 0``; the
    final ragged chunk is now masked in-kernel."""
    from repro.kernels.tocab_spmm.ops import tocab_spmm

    g, dg, bg, _ = setup
    assert bg.edge_budget % 7, "pick a chunk that does not divide evenly"
    x = _vals(g.n, seed=10)
    ref = np.asarray(tocab_pull(bg, x))
    for mode in ("onehot", "scatter"):
        np.testing.assert_allclose(
            np.asarray(tocab_spmm(bg, x, mode=mode, chunk=chunk)),
            ref, rtol=2e-5, atol=2e-5)

"""jit'd wrapper selecting Pallas flash attention vs XLA reference.

The models call :func:`attention`; on the CPU container Pallas runs in
interpret mode (slow, correctness only), so the default backend is the XLA
reference path and the dry-run lowers the XLA path.  On real TPU hardware
``backend='pallas'`` activates the kernel.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["attention"]


@partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "backend", "interpret"),
)
def attention(
    q, k, v,
    *,
    scale=None,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    backend: str = "xla",
    interpret: bool = True,
):
    if backend == "pallas":
        return flash_attention_pallas(
            q, k, v,
            scale=scale, causal=causal, window=window, softcap=softcap,
            interpret=interpret,
        )
    return attention_ref(
        q, k, v, scale=scale, causal=causal, window=window, softcap=softcap
    )

"""Pure-jnp oracle for flash attention (dense softmax, fp32)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q, k, v,
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
):
    """(B, Hq, Sq, D) x (B, Hkv, Skv, D)² → (B, Hq, Sq, D); GQA by repeat."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window > 0:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

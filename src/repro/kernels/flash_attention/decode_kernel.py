"""Flash-decoding (split-KV) Pallas kernel for the decode_* cells.

Decode attention (q_len=1 vs a long KV cache) is bandwidth-bound and has no
parallelism along the query axis — FlashDecoding++-style splitting
parallelizes the *KV* axis instead: the grid covers (batch, head, kv_split),
each split streams its KV chunk with an online-softmax accumulator and
emits partial (max, sumexp, acc); the partials are merged with a logsumexp
combine outside the kernel (numerically exact).

This is the TPU analogue of the paper's insight applied to decode: confine
each grid step's working set (one KV chunk) to VMEM, and make the merge a
separate dense pass — the same two-phase structure as TOCAB's partials +
reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_decode_pallas", "flash_decode_ref"]

NEG_INF = -1e30


def _decode_kernel(
    q_ref,  # (1, 1, Hq_grp, d)   — the group's query rows (one kv head)
    k_ref,  # (1, 1, split, d)
    v_ref,  # (1, 1, split, d)
    m_ref,  # (1, 1, 1, Hq_grp)   — partial max
    l_ref,  # (1, 1, 1, Hq_grp)   — partial sumexp
    o_ref,  # (1, 1, Hq_grp, d)   — partial (unnormalized) output
    *,
    scale: float,
    kv_len: int,
    split: int,
    softcap: float,
):
    si = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (Hq_grp, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (split, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    # mask positions beyond the true cache length
    pos = si * split + jax.lax.iota(jnp.int32, split)
    s = jnp.where((pos < kv_len)[None, :], s, NEG_INF)
    m = s.max(axis=-1)  # (Hq_grp,)
    p = jnp.exp(s - m[:, None])
    l = p.sum(axis=-1)
    acc = jax.lax.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[0, 0, 0, :] = m
    l_ref[0, 0, 0, :] = l
    o_ref[0, 0] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "kv_splits", "kv_len", "softcap", "interpret"),
)
def flash_decode_pallas(
    q,  # (B, Hq, 1, d) — one new token
    k,  # (B, Hkv, S, d)
    v,  # (B, Hkv, S, d)
    *,
    scale: float | None = None,
    kv_len: int | None = None,  # live cache length (≤ S); None → S
    kv_splits: int = 8,
    softcap: float = 0.0,
    interpret: bool = True,
):
    B, Hq, _, d = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = d ** -0.5
    kv_len = S if kv_len is None else kv_len
    while S % kv_splits:
        kv_splits //= 2
    split = S // kv_splits
    # queries regrouped so each grid step serves one kv head's q-group
    qg = q.reshape(B, Hkv, group, d)

    grid = (B, Hkv, kv_splits)
    kernel = functools.partial(
        _decode_kernel, scale=float(scale), kv_len=int(kv_len),
        split=split, softcap=float(softcap))
    m, l, o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, split, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, split, d), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, group), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, group), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, group, d), lambda b, h, s: (b, h * kv_splits + s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, kv_splits, group), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, kv_splits, group), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv * kv_splits, group, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    # logsumexp merge of the split partials (the "reduction phase")
    o = o.reshape(B, Hkv, kv_splits, group, d)
    m_star = m.max(axis=2, keepdims=True)  # (B, Hkv, 1, group)
    alpha = jnp.exp(m - m_star)  # (B, Hkv, splits, group)
    l_total = (l * alpha).sum(axis=2)  # (B, Hkv, group)
    o_total = (o * alpha[..., None]).sum(axis=2)  # (B, Hkv, group, d)
    out = o_total / jnp.maximum(l_total, 1e-30)[..., None]
    return out.reshape(B, Hq, 1, d).astype(q.dtype)


def flash_decode_ref(q, k, v, *, scale=None, kv_len=None, softcap=0.0):
    """Dense oracle: plain masked softmax attention at q_len=1."""
    B, Hq, _, d = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = d ** -0.5
    kv_len = S if kv_len is None else kv_len
    kk = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32) * scale, kk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.arange(S) < kv_len
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", p, vv).astype(q.dtype)

"""Pallas TPU flash attention (streaming softmax) for the LM architectures.

Features required by the assigned archs: GQA (grouped KV heads), causal
masking, sliding-window attention (Mixtral), attention logit soft-capping
(Gemma-2), bidirectional mode (BERT4Rec).

Grid = (batch, q_heads, q_tiles).  K/V for the head's KV group are pinned in
VMEM by the BlockSpec (one (S, D) slab per grid step); the kernel streams KV
tiles with an online-softmax accumulator.  Causal / out-of-window KV tiles
are skipped entirely (block-level early-out) — the same "don't touch data
you don't need" discipline as TOCAB's compaction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas", "NEG_INF"]

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # (1, 1, q_tile, d)
    k_ref,  # (1, 1, kv_len, d)
    v_ref,  # (1, 1, kv_len, d)
    o_ref,  # (1, 1, q_tile, d)
    *,
    kv_tile: int,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
):
    q_tile, d = q_ref.shape[2], q_ref.shape[3]
    kv_len = k_ref.shape[2]
    qi = pl.program_id(2)
    q_pos = qi * q_tile + jax.lax.iota(jnp.int32, q_tile)  # global q rows

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale
    m0 = jnp.full((q_tile,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_tile,), jnp.float32)
    acc0 = jnp.zeros((q_tile, d), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        kv_start = j * kv_tile
        kv_pos = kv_start + jax.lax.iota(jnp.int32, kv_tile)

        def compute(_):
            k = k_ref[0, 0, pl.dslice(kv_start, kv_tile), :].astype(jnp.float32)
            v = v_ref[0, 0, pl.dslice(kv_start, kv_tile), :].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (q_tile, kv_tile)
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            mask = jnp.ones((q_tile, kv_tile), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window > 0:
                mask &= (q_pos[:, None] - kv_pos[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[:, None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[:, None] + jax.lax.dot(
                p, v, preferred_element_type=jnp.float32
            )
            return m_new, l_new, acc_new

        # block-level early-out: skip KV tiles fully above the causal
        # diagonal or fully left of the sliding window
        relevant = jnp.bool_(True)
        if causal:
            relevant &= kv_start <= qi * q_tile + (q_tile - 1)
        if window > 0:
            relevant &= (kv_start + kv_tile - 1) > (qi * q_tile - window)
        return jax.lax.cond(relevant, compute, lambda _: (m, l, acc), None)

    m, l, acc = jax.lax.fori_loop(0, kv_len // kv_tile, body, (m0, l0, acc0))
    l = jnp.maximum(l, 1e-30)
    o_ref[0, 0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "q_tile", "kv_tile", "causal", "window", "softcap", "scale", "interpret",
    ),
)
def flash_attention_pallas(
    q,  # (B, Hq, Sq, D)
    k,  # (B, Hkv, Skv, D)
    v,  # (B, Hkv, Skv, D)
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int = 0,  # 0 = unlimited; >0 = sliding window width
    softcap: float = 0.0,  # 0 = disabled
    q_tile: int = 128,
    kv_tile: int = 128,
    interpret: bool = True,
):
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    q_tile = min(q_tile, Sq)
    kv_tile = min(kv_tile, Skv)
    assert Sq % q_tile == 0 and Skv % kv_tile == 0
    if scale is None:
        scale = D ** -0.5

    grid = (B, Hq, Sq // q_tile)
    kernel = functools.partial(
        _attn_kernel,
        kv_tile=kv_tile,
        scale=float(scale),
        causal=causal,
        window=int(window),
        softcap=float(softcap),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_tile, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Skv, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_tile, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)

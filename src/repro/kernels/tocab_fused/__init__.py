from .ops import fused_edge_reduce, fused_pull, fused_push

__all__ = ["fused_pull", "fused_push", "fused_edge_reduce"]

"""Public fused-TOCAB entry points: backend pick, padding, telemetry.

``fused_pull`` / ``fused_push`` / ``fused_edge_reduce`` are what
``repro.core.tocab``'s ``impl="fused"`` dispatches to.  Two backends:

* ``"pallas"`` — the persistent kernels in :mod:`.kernel` (compiled on
  TPU; ``interpret=True`` elsewhere, for validation only — interpret mode
  pads features to the 128 lane width, pure overhead off-TPU);
* ``"jax"`` — the scan-over-blocks path in :mod:`.ref`, the default off
  TPU: same fused dataflow (output is the scan carry, no partial slab),
  no lane padding.

Both are bit-identical to the slab engines (tests/test_fused.py).  Each
call records what fusion removed: ``tocab.fused_blocks`` counts blocks run
through the fused path and ``tocab.partial_hbm_bytes_saved`` the partial /
``block_contrib`` slab bytes that never touched HBM.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.partition import BlockedGraph
from repro.obs.metrics import registry as _obs
from repro.resilience import chaos as _chaos

from .kernel import LANE, fused_pull_pallas, fused_push_pallas
from .ref import fused_edge_reduce_ref, fused_pull_ref, fused_push_ref

__all__ = ["fused_pull", "fused_push", "fused_edge_reduce",
           "default_backend", "LANE"]


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jax"


def _roundup(x: int, to: int) -> int:
    return -(-x // to) * to


def _record_fused(bg: BlockedGraph, engine: str, tail: Tuple[int, ...],
                  itemsize: int):
    """Trace-time telemetry (static shapes — free at runtime)."""
    _obs.counter(
        "tocab.fused_blocks", "cache blocks run through the fused path"
    ).inc(bg.num_blocks, engine=engine, direction=bg.direction)
    saved = bg.num_blocks * bg.local_budget * itemsize
    saved *= math.prod(tail) if tail else 1
    _obs.counter(
        "tocab.partial_hbm_bytes_saved",
        "partial/contrib slab bytes the fused path never materializes",
    ).inc(saved, engine=engine, direction=bg.direction)


def _pallas_edges(bg: BlockedGraph, combine):
    """Edge-value / mask slabs + weighted flag in the kernels' layout."""
    from repro.core.balance import UNWEIGHTED

    mask_f = bg.edge_mask.astype(jnp.float32)
    ev = bg.edge_vals
    if combine is UNWEIGHTED:
        combine, ev = None, None
    if ev is None:
        return mask_f, mask_f, False, combine  # ev slot unused
    return jnp.where(bg.edge_mask, ev, 0.0), mask_f, True, combine


def _epilogue_arr(epilogue) -> Tuple[jnp.ndarray, bool]:
    if epilogue is None:
        return jnp.asarray([[1.0, 0.0]], jnp.float32), False
    mul, add = epilogue
    eps = jnp.stack([jnp.asarray(mul, jnp.float32).reshape(()),
                     jnp.asarray(add, jnp.float32).reshape(())])
    return eps[None, :], True


def _check_epilogue(reduce: str, epilogue):
    if epilogue is not None and reduce != "sum":
        raise ValueError(
            f"epilogue fusion is affine (out*mul+add) — only the sum "
            f"semiring supports it, got reduce={reduce!r}")


def fused_pull(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    epilogue: Optional[Tuple] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    block_order: Optional[Sequence[int]] = None,
    tile_rows: Optional[int] = None,
    chunk: int = 512,
):
    """out[dst] = ⊕ values[src] (⊗ edge_val), partials never leaving fast
    memory; optional affine epilogue ``out*mul + add`` fused in."""
    _chaos.maybe_raise("kernel.tocab_fused.op")  # opt-in fault-injection site
    assert bg.direction == "pull"
    _check_epilogue(reduce, epilogue)
    backend = backend or default_backend()
    _record_fused(bg, "fused_pull", values.shape[1:],
                  jnp.dtype(values.dtype).itemsize)
    if backend == "jax":
        return fused_pull_ref(bg, values, reduce, combine, epilogue,
                              block_order)
    if backend != "pallas":
        raise ValueError(f"unknown fused backend {backend!r}")
    if values.ndim > 2:
        raise NotImplementedError(
            "pallas fused pull supports (n,) or (n, d) values")
    squeeze = values.ndim == 1
    x = values[:, None] if squeeze else values
    n, d = x.shape
    d_pad = _roundup(d, LANE)
    rows_pad = bg.num_blocks * bg.block_size
    vals = jnp.zeros((rows_pad, d_pad), jnp.float32)
    vals = vals.at[:n, :d].set(x.astype(jnp.float32))
    ev, mask_f, weighted, combine = _pallas_edges(bg, combine)
    widx, cidx, idmap = bg.window_idx, bg.compact_idx, bg.id_map
    if block_order is not None:
        idx = jnp.asarray(tuple(block_order), jnp.int32)
        widx, cidx, ev, mask_f, idmap = (
            jnp.take(a, idx, axis=0) for a in (widx, cidx, ev, mask_f, idmap))
        vals = jnp.take(vals.reshape(bg.num_blocks, bg.block_size, d_pad),
                        idx, axis=0).reshape(rows_pad, d_pad)
    eps, fuse_eps = _epilogue_arr(epilogue)
    tile_rows = tile_rows or _roundup(bg.n, 8)
    out = fused_pull_pallas(
        vals, widx, cidx, ev, mask_f, idmap, eps,
        block_size=bg.block_size, local_budget=bg.local_budget,
        tile_rows=tile_rows, num_tiles=1, chunk=chunk, reduce=reduce,
        combine=combine, weighted=weighted, fuse_epilogue=fuse_eps,
        interpret=interpret if interpret is not None
        else jax.default_backend() != "tpu")
    out = out[: bg.n, :d]
    return out[:, 0] if squeeze else out


def fused_push(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    epilogue: Optional[Tuple] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    block_order: Optional[Sequence[int]] = None,
    chunk: int = 512,
):
    """Push with the ``block_contrib`` gather kept in fast memory.  Blocks
    own disjoint destination windows, so any ``block_order`` (the balance
    module's bin-major one included) is bit-identical."""
    _chaos.maybe_raise("kernel.tocab_fused.op")  # opt-in fault-injection site
    assert bg.direction == "push"
    _check_epilogue(reduce, epilogue)
    backend = backend or default_backend()
    _record_fused(bg, "fused_push", values.shape[1:],
                  jnp.dtype(values.dtype).itemsize)
    if block_order is None and bg.schedule is not None:
        from repro.core.balance import fused_block_order

        block_order = fused_block_order(bg)
    if backend == "jax":
        return fused_push_ref(bg, values, reduce, combine, epilogue,
                              block_order)
    if backend != "pallas":
        raise ValueError(f"unknown fused backend {backend!r}")
    if values.ndim > 2:
        raise NotImplementedError(
            "pallas fused push supports (n,) or (n, d) values")
    squeeze = values.ndim == 1
    x = values[:, None] if squeeze else values
    n, d = x.shape
    d_pad = _roundup(d, LANE)
    n_pad = _roundup(n + 1, 8)  # padded id_map entries (= n) must read 0
    vals = jnp.zeros((n_pad, d_pad), jnp.float32)
    vals = vals.at[:n, :d].set(x.astype(jnp.float32))
    ev, mask_f, weighted, combine = _pallas_edges(bg, combine)
    widx, cidx, idmap = bg.window_idx, bg.compact_idx, bg.id_map
    order = None
    if block_order is not None:
        order = tuple(int(b) for b in block_order)
        idx = jnp.asarray(order, jnp.int32)
        widx, cidx, ev, mask_f, idmap = (
            jnp.take(a, idx, axis=0) for a in (widx, cidx, ev, mask_f, idmap))
    eps, fuse_eps = _epilogue_arr(epilogue)
    out = fused_push_pallas(
        vals, widx, cidx, ev, mask_f, idmap, eps,
        block_size=bg.block_size, local_budget=bg.local_budget, chunk=chunk,
        reduce=reduce, combine=combine, weighted=weighted,
        fuse_epilogue=fuse_eps,
        interpret=interpret if interpret is not None
        else jax.default_backend() != "tpu")
    if order is not None:
        inv = [0] * bg.num_blocks
        for j, b in enumerate(order):
            inv[b] = j
        out = jnp.take(out.reshape(bg.num_blocks, bg.block_size, d_pad),
                       jnp.asarray(inv, jnp.int32), axis=0
                       ).reshape(bg.num_blocks * bg.block_size, d_pad)
    out = out[: bg.n, :d]
    return out[:, 0] if squeeze else out


def fused_edge_reduce(
    bg: BlockedGraph,
    flat_edge_vals: jnp.ndarray,
    reduce: str = "sum",
    epilogue: Optional[Tuple] = None,
    backend: Optional[str] = None,
):
    """Edge-value → compacted-side aggregate, no partial slab.  The scan
    path serves both backends — messages come from the blocked edge-value
    slab, not a value window, so there is no gather to confine."""
    _chaos.maybe_raise("kernel.tocab_fused.op")  # opt-in fault-injection site
    _check_epilogue(reduce, epilogue)
    del backend  # single implementation today; kept for API symmetry
    _record_fused(bg, "fused_edge_reduce", flat_edge_vals.shape[1:],
                  jnp.dtype(flat_edge_vals.dtype).itemsize)
    return fused_edge_reduce_ref(bg, flat_edge_vals, reduce, epilogue)

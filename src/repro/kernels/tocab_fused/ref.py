"""Fused TOCAB reference path: scan over blocks, accumulate in the carry.

This is the off-TPU backend of ``impl="fused"`` and the bit-identity anchor
for the Pallas kernel.  The slab engines (``tocab_pull_partials`` →
``reduce_partials``) materialize a ``(num_blocks, local_budget, *tail)``
partial slab in HBM and pay a second full pass to merge it; here the output
array *is* the accumulator — a ``lax.scan`` whose carry is the result folds
each block's compacted partial straight in, so the only per-block
intermediate is one ``(local_budget, *tail)`` buffer that XLA keeps in the
loop body (registers/L1, never an HBM slab).

Bit-identity with the slab path holds because both apply the same per-
destination operand sequence in the same order: within a block, messages
accumulate in edge-slot order (scatter/segment updates apply in operand
order); across blocks, destinations accumulate in block-major order —
exactly the order ``reduce_partials``'s flat segment reduce visits the slab.
Padded edge slots contribute the identity to compact row 0 (pull) or are
dropped (push), mirroring the slab engines slot for slot.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.partition import REDUCE_IDENTITY, BlockedGraph

__all__ = ["fused_pull_ref", "fused_push_ref", "fused_edge_reduce_ref"]

_ACCUM = {
    "sum": lambda out, ids, p: out.at[ids].add(p, mode="drop"),
    "min": lambda out, ids, p: out.at[ids].min(p, mode="drop"),
    "max": lambda out, ids, p: out.at[ids].max(p, mode="drop"),
}


def _apply_epilogue(out, epilogue):
    if epilogue is None:
        return out
    mul, add = epilogue
    return out * mul + add


def _block_order(bg: BlockedGraph, order: Optional[Sequence[int]]):
    if order is None:
        return None
    order = tuple(int(b) for b in order)
    if sorted(order) != list(range(bg.num_blocks)):
        raise ValueError(
            f"block_order must be a permutation of range({bg.num_blocks})")
    return order


def _permuted(order, *arrays):
    if order is None:
        return arrays
    idx = jnp.asarray(order, jnp.int32)
    return tuple(None if a is None else jnp.take(a, idx, axis=0)
                 for a in arrays)


def fused_pull_ref(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    epilogue: Optional[Tuple] = None,
    block_order: Optional[Sequence[int]] = None,
):
    """out[dst] = ⊕ per-block compacted partials, accumulated in place.

    NB: a non-natural ``block_order`` changes the floating-point summation
    order across blocks — bit-identity with the slab path needs the default
    (natural) order.
    """
    assert bg.direction == "pull"
    from repro.core.tocab import _edge_messages, segment_reduce

    order = _block_order(bg, block_order)
    widx, cidx, mask, idmap, lo, ev = _permuted(
        order, bg.window_idx, bg.compact_idx, bg.edge_mask, bg.id_map,
        bg.window_lo(), bg.edge_vals)
    tail = values.shape[1:]
    out0 = jnp.full((bg.n,) + tail, REDUCE_IDENTITY[reduce], values.dtype)
    accum = _ACCUM[reduce]

    def body(out, xs):
        widx_b, cidx_b, mask_b, idmap_b, lo_b = xs[:5]
        ev_b = xs[5] if len(xs) > 5 else None
        msgs = _edge_messages(values, widx_b + lo_b, ev_b, mask_b, reduce,
                              combine)
        partial = segment_reduce(msgs, cidx_b, bg.local_budget, reduce)
        # padded id_map rows point at n — out of range → dropped
        return accum(out, idmap_b, partial), None

    xs = (widx, cidx, mask, idmap, lo) + (() if ev is None else (ev,))
    out, _ = jax.lax.scan(body, out0, xs)
    return _apply_epilogue(out, epilogue)


def fused_push_ref(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    epilogue: Optional[Tuple] = None,
    block_order: Optional[Sequence[int]] = None,
):
    """Push: each block owns a disjoint destination window, so the scan
    emits finished windows (stacked then deinterleaved) — the per-block
    ``block_contrib`` gather stays inside the loop body instead of being a
    ``(num_blocks, local_budget)`` HBM slab.  Windows are independent, so
    any ``block_order`` (e.g. the bin-major one) is bit-identical."""
    assert bg.direction == "push"
    ident = REDUCE_IDENTITY[reduce]
    order = _block_order(bg, block_order)
    widx, cidx, mask, idmap, ev = _permuted(
        order, bg.window_idx, bg.compact_idx, bg.edge_mask, bg.id_map,
        bg.edge_vals)
    tail = values.shape[1:]

    def body(_, xs):
        widx_b, cidx_b, mask_b, idmap_b = xs[:4]
        ev_b = xs[4] if len(xs) > 4 else None
        # the block's few distinct sources, fetched once (the reuse win)
        contrib = jnp.take(values, idmap_b, axis=0, mode="fill", fill_value=0)
        msgs = jnp.take(contrib, cidx_b, axis=0)
        if ev_b is not None:
            while ev_b.ndim < msgs.ndim:
                ev_b = ev_b[..., None]
        if combine is not None:
            msgs = combine(msgs, ev_b)
        elif ev_b is not None:
            msgs = msgs * ev_b
        mk = mask_b if msgs.ndim == mask_b.ndim else mask_b[..., None]
        msgs = jnp.where(mk, msgs, jnp.asarray(ident, msgs.dtype))
        # padded edges → row block_size → dropped (slab: segment n)
        wid = jnp.where(mask_b, widx_b, bg.block_size)
        from repro.core.tocab import segment_reduce

        win = segment_reduce(msgs, wid, bg.block_size + 1, reduce)
        return None, win[: bg.block_size]

    xs = (widx, cidx, mask, idmap) + (() if ev is None else (ev,))
    _, wins = jax.lax.scan(body, None, xs)  # (nb, block_size) + tail
    if order is not None:
        inv = [0] * bg.num_blocks
        for j, b in enumerate(order):
            inv[b] = j
        wins = jnp.take(wins, jnp.asarray(inv, jnp.int32), axis=0)
    out = wins.reshape((bg.num_blocks * bg.block_size,) + tail)[: bg.n]
    return _apply_epilogue(out, epilogue)


def fused_edge_reduce_ref(
    bg: BlockedGraph,
    flat_edge_vals: jnp.ndarray,
    reduce: str = "sum",
    epilogue: Optional[Tuple] = None,
):
    """Edge values → compacted-side aggregate without the partial slab.

    The ``(num_blocks, edge_budget)`` blocked edge-value slab is the
    *input* layout (unavoidable); what the fused path removes is the
    ``(num_blocks, local_budget)`` partial intermediate."""
    from repro.core.tocab import blocked_edge_values, segment_reduce

    vals = blocked_edge_values(bg, flat_edge_vals)
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], vals.dtype)
    tail = vals.shape[2:]
    out0 = jnp.full((bg.n,) + tail, ident, vals.dtype)
    accum = _ACCUM[reduce]

    def body(out, xs):
        vals_b, cidx_b, mask_b, idmap_b = xs
        mk = mask_b
        while mk.ndim < vals_b.ndim:
            mk = mk[..., None]
        masked = jnp.where(mk, vals_b, ident)
        partial = segment_reduce(masked, cidx_b, bg.local_budget, reduce)
        return accum(out, idmap_b, partial), None

    out, _ = jax.lax.scan(
        body, out0, (vals, bg.compact_idx, bg.edge_mask, bg.id_map))
    return _apply_epilogue(out, epilogue)

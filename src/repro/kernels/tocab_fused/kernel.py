"""Persistent Pallas kernels for the fused TOCAB pipeline.

The slab engines run three kernels per iteration — phase-2 partials, the
phase-3 segment reduce, and the per-vertex apply — with a
``(num_blocks, local_budget, d)`` partial slab round-tripping through HBM
between them.  These kernels fuse all three:

* **pull** — grid ``(num_tiles, num_blocks)``: the *output tile* BlockSpec
  ignores the inner (block) dimension, so the tile stays VMEM-resident
  while every cache block streams its gather/edge/mask windows through
  double-buffered DMA (Pallas pipelines the next block's windows while the
  current one computes).  Each block accumulates into a local
  ``(local_budget, d)`` register/VMEM buffer and folds it straight into the
  resident tile via ``id_map`` — the partial slab never exists.  On the last
  block the epilogue (``out·mul + add``: PageRank damping / SpMV scale)
  is applied in place, so the apply kernel disappears too.
* **push** — grid ``(num_blocks,)``: row blocking gives each block a
  *disjoint* destination window (= the output tile), and the whole source
  vector rides a constant BlockSpec so it is fetched once and stays
  resident; the ``block_contrib`` gather happens in VMEM instead of
  materializing an HBM slab.

Accumulation order matches the slab engines' scatter order exactly (chunked
``.at[].add`` in edge-slot order within a block, block-major across
blocks), so results are bit-identical — asserted in tests/test_fused.py.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.partition import REDUCE_IDENTITY

__all__ = ["fused_pull_pallas", "fused_push_pallas", "LANE"]

LANE = 128  # TPU lane width; feature dims are padded to multiples of this


def _pick_chunk(edge_budget: int, chunk: int) -> int:
    """Largest divisor of ``edge_budget`` ≤ ``chunk`` (edge budgets are
    128-padded, so this never degrades below 128 for the default 512)."""
    chunk = max(1, min(chunk, edge_budget))
    while edge_budget % chunk:
        chunk -= 1
    return chunk


def _chunk_messages(window, widx_ref, cidx_ref, ev_ref, mask_ref, sl,
                    reduce: str, combine, weighted: bool):
    """Gather + weight + mask one edge chunk from the VMEM-resident refs."""
    widx = widx_ref[0, sl]
    cidx = cidx_ref[0, sl]
    msgs = jnp.take(window, widx, axis=0)  # confined random read (VMEM)
    if weighted:
        ev = ev_ref[0, sl][:, None]
        msgs = combine(msgs, ev) if combine is not None else msgs * ev
    mask = mask_ref[0, sl] > 0
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], msgs.dtype)
    return jnp.where(mask[:, None], msgs, ident), cidx, mask


def _fused_pull_kernel(
    win_ref,    # (block_size, d)       the block's source-value window
    widx_ref,   # (1, edge_budget)      src index within the window
    cidx_ref,   # (1, edge_budget)      compacted dst local id (pad → 0)
    ev_ref,     # (1, edge_budget)      edge values (ignored if unweighted)
    mask_ref,   # (1, edge_budget)      1.0 on real edges, 0.0 on padding
    idmap_ref,  # (1, local_budget)     local dst → global dst (pad → n)
    eps_ref,    # (1, 2)                epilogue (mul, add)
    out_ref,    # (tile_rows, d)        VMEM-resident output tile
    *,
    chunk: int,
    reduce: str,
    combine: Optional[Callable],
    weighted: bool,
    fuse_epilogue: bool,
):
    t = pl.program_id(0)
    b = pl.program_id(1)
    nb = pl.num_programs(1)
    local_budget = idmap_ref.shape[1]
    d = out_ref.shape[1]
    tile_rows = out_ref.shape[0]
    edge_budget = widx_ref.shape[1]
    ident = REDUCE_IDENTITY[reduce]

    @pl.when(b == 0)
    def _init_tile():
        out_ref[...] = jnp.full((tile_rows, d), ident, out_ref.dtype)

    def body(c, acc):
        sl = pl.dslice(c * chunk, chunk)
        msgs, cidx, _ = _chunk_messages(
            win_ref[...], widx_ref, cidx_ref, ev_ref, mask_ref, sl,
            reduce, combine, weighted)
        # padded slots carry the identity and (stored) cidx 0 — the exact
        # operand stream of the slab path's flat segment reduce
        if reduce == "sum":
            return acc.at[cidx].add(msgs)
        if reduce == "min":
            return acc.at[cidx].min(msgs)
        return acc.at[cidx].max(msgs)

    acc = jnp.full((local_budget, d), ident, jnp.float32)
    acc = jax.lax.fori_loop(0, edge_budget // chunk, body, acc, unroll=False)

    # Fold the block's compacted partial straight into the resident tile.
    gid = idmap_ref[0, :]
    loc = gid - t * tile_rows
    oob = (loc < 0) | (loc >= tile_rows)
    loc = jnp.where(oob, tile_rows, loc)  # out-of-tile → dropped
    tile = out_ref[...]
    if reduce == "sum":
        tile = tile.at[loc].add(acc, mode="drop")
    elif reduce == "min":
        tile = tile.at[loc].min(acc, mode="drop")
    else:
        tile = tile.at[loc].max(acc, mode="drop")
    out_ref[...] = tile

    if fuse_epilogue:
        @pl.when(b == nb - 1)
        def _epilogue():
            out_ref[...] = out_ref[...] * eps_ref[0, 0] + eps_ref[0, 1]


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "local_budget", "tile_rows", "num_tiles",
                     "chunk", "reduce", "combine", "weighted",
                     "fuse_epilogue", "interpret"),
)
def fused_pull_pallas(
    values,       # f32[num_blocks*block_size, d]  (padded)
    window_idx,   # i32[num_blocks, edge_budget]
    compact_idx,  # i32[num_blocks, edge_budget]
    edge_vals,    # f32[num_blocks, edge_budget]
    edge_mask,    # f32[num_blocks, edge_budget]  (1.0 real / 0.0 pad)
    id_map,       # i32[num_blocks, local_budget]  (pad = n → dropped)
    epilogue,     # f32[1, 2]  (mul, add); identity when fuse_epilogue=False
    *,
    block_size: int,
    local_budget: int,
    tile_rows: int,
    num_tiles: int = 1,
    chunk: int = 512,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    weighted: bool = True,
    fuse_epilogue: bool = False,
    interpret: bool = True,
):
    """Fused pull: returns f32[num_tiles*tile_rows, d] — no partial slab.

    A single tile sized to the padded output covers every graph in the
    repo's suite; multi-tile runs trade VMEM for replaying each block's
    edge stream once per tile."""
    num_blocks, edge_budget = window_idx.shape
    d = values.shape[1]
    assert values.shape[0] == num_blocks * block_size, (
        f"values must be padded to num_blocks*block_size, got {values.shape}")
    chunk = _pick_chunk(edge_budget, chunk)
    grid = (num_tiles, num_blocks)
    kernel = functools.partial(
        _fused_pull_kernel, chunk=chunk, reduce=reduce, combine=combine,
        weighted=weighted, fuse_epilogue=fuse_epilogue)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_size, d), lambda t, b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda t, b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda t, b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda t, b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda t, b: (b, 0)),
            pl.BlockSpec((1, local_budget), lambda t, b: (b, 0)),
            pl.BlockSpec((1, 2), lambda t, b: (0, 0)),
        ],
        # index map ignores b → the tile stays resident across the inner
        # (cache block) grid dimension and is flushed once per tile
        out_specs=pl.BlockSpec((tile_rows, d), lambda t, b: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * tile_rows, d),
                                       jnp.float32),
        interpret=interpret,
    )(values, window_idx, compact_idx, edge_vals, edge_mask, id_map,
      epilogue)


def _fused_push_kernel(
    values_ref,  # (n_pad, d)            whole source vector, VMEM-resident
    widx_ref,    # (1, edge_budget)      dst index within the block window
    cidx_ref,    # (1, edge_budget)      compacted src local id
    ev_ref,      # (1, edge_budget)
    mask_ref,    # (1, edge_budget)
    idmap_ref,   # (1, local_budget)     local src → global src (pad = n)
    eps_ref,     # (1, 2)
    out_ref,     # (block_size, d)       the block's disjoint dst window
    *,
    chunk: int,
    reduce: str,
    combine: Optional[Callable],
    weighted: bool,
    fuse_epilogue: bool,
):
    block_size = out_ref.shape[0]
    edge_budget = widx_ref.shape[1]
    ident = REDUCE_IDENTITY[reduce]

    # in-VMEM block_contrib: each distinct source fetched once per block
    contrib = jnp.take(values_ref[...], idmap_ref[0, :], axis=0)

    def body(c, acc):
        sl = pl.dslice(c * chunk, chunk)
        cidx = cidx_ref[0, sl]
        msgs = jnp.take(contrib, cidx, axis=0)
        if weighted:
            ev = ev_ref[0, sl][:, None]
            msgs = combine(msgs, ev) if combine is not None else msgs * ev
        mask = mask_ref[0, sl] > 0
        msgs = jnp.where(mask[:, None], msgs,
                         jnp.asarray(ident, msgs.dtype))
        # padded edges → scratch row block_size (slab: segment n → dropped)
        wid = jnp.where(mask, widx_ref[0, sl], block_size)
        if reduce == "sum":
            return acc.at[wid].add(msgs, mode="drop")
        if reduce == "min":
            return acc.at[wid].min(msgs, mode="drop")
        return acc.at[wid].max(msgs, mode="drop")

    d = out_ref.shape[1]
    acc = jnp.full((block_size, d), ident, jnp.float32)
    acc = jax.lax.fori_loop(0, edge_budget // chunk, body, acc, unroll=False)
    if fuse_epilogue:
        acc = acc * eps_ref[0, 0] + eps_ref[0, 1]
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "local_budget", "chunk", "reduce",
                     "combine", "weighted", "fuse_epilogue", "interpret"),
)
def fused_push_pallas(
    values,       # f32[n_pad, d]  (n_pad ≥ n+1 so padded id_map reads 0)
    window_idx,   # i32[num_blocks, edge_budget]
    compact_idx,  # i32[num_blocks, edge_budget]
    edge_vals,    # f32[num_blocks, edge_budget]
    edge_mask,    # f32[num_blocks, edge_budget]
    id_map,       # i32[num_blocks, local_budget]
    epilogue,     # f32[1, 2]
    *,
    block_size: int,
    local_budget: int,
    chunk: int = 512,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    weighted: bool = True,
    fuse_epilogue: bool = False,
    interpret: bool = True,
):
    """Fused push: returns f32[num_blocks*block_size, d] (slice to n).

    The ``block_contrib`` slab of the slab engine is replaced by an
    in-kernel gather from the resident ``values``."""
    num_blocks, edge_budget = window_idx.shape
    n_pad, d = values.shape
    chunk = _pick_chunk(edge_budget, chunk)
    kernel = functools.partial(
        _fused_push_kernel, chunk=chunk, reduce=reduce, combine=combine,
        weighted=weighted, fuse_epilogue=fuse_epilogue)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            # constant index map → fetched once, resident across all blocks
            pl.BlockSpec((n_pad, d), lambda b: (0, 0)),
            pl.BlockSpec((1, edge_budget), lambda b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda b: (b, 0)),
            pl.BlockSpec((1, local_budget), lambda b: (b, 0)),
            pl.BlockSpec((1, 2), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_size, d), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks * block_size, d),
                                       jnp.float32),
        interpret=interpret,
    )(values, window_idx, compact_idx, edge_vals, edge_mask, id_map,
      epilogue)

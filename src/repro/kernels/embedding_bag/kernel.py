"""Pallas TPU embedding-bag kernel — TOCAB applied to the recsys hot loop.

JAX has no native EmbeddingBag; the framework builds it from gather +
segment-reduce (ref.py).  This kernel is the cache-blocked fast path: the
embedding table is processed in **row blocks pinned in VMEM** (the paper's
pull-direction source window), and every bag tile accumulates the
contributions of indices falling inside the current block — the classic
TOCAB trade: each bag's index list is rescanned once per block (cheap,
sequential, VMEM-resident) in exchange for ALL table reads hitting VMEM
instead of random HBM lines.

Grid = (bag_tiles, table_blocks); the output block is revisited across the
table_blocks axis and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["embedding_bag_pallas"]


def _kernel(
    tbl_ref,  # (rows_per_block, d)   VMEM window of the table
    idx_ref,  # (bag_tile, L)
    w_ref,  # (bag_tile, L)           weights (0 = padding)
    o_ref,  # (bag_tile, d)
    *,
    rows_per_block: int,
):
    blk = pl.program_id(1)
    lo = blk * rows_per_block
    bag_tile, L = idx_ref.shape
    d = tbl_ref.shape[1]

    idx = idx_ref[...]
    rel = idx - lo
    valid = (rel >= 0) & (rel < rows_per_block)
    relc = jnp.clip(rel, 0, rows_per_block - 1)
    gathered = jnp.take(tbl_ref[...], relc.reshape(-1), axis=0)
    gathered = gathered.reshape(bag_tile, L, d)
    w = w_ref[...] * valid.astype(w_ref.dtype)
    contrib = (gathered * w[..., None]).sum(axis=1)

    @pl.when(blk == 0)
    def _init():
        o_ref[...] = contrib.astype(o_ref.dtype)

    @pl.when(blk > 0)
    def _accum():
        o_ref[...] += contrib.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("rows_per_block", "bag_tile", "interpret")
)
def embedding_bag_pallas(
    table,  # f32[vocab_padded, d]   vocab_padded % rows_per_block == 0
    indices,  # i32[B, L]
    weights,  # f32[B, L]            0 where padded
    *,
    rows_per_block: int = 4096,
    bag_tile: int = 128,
    interpret: bool = True,
):
    vocab, d = table.shape
    B, L = indices.shape
    assert vocab % rows_per_block == 0, (vocab, rows_per_block)
    assert B % bag_tile == 0, (B, bag_tile)
    grid = (B // bag_tile, vocab // rows_per_block)
    return pl.pallas_call(
        functools.partial(_kernel, rows_per_block=rows_per_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, d), lambda i, b: (b, 0)),
            pl.BlockSpec((bag_tile, L), lambda i, b: (i, 0)),
            pl.BlockSpec((bag_tile, L), lambda i, b: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bag_tile, d), lambda i, b: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), table.dtype),
        interpret=interpret,
    )(table, indices, weights)

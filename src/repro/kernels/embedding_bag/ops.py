"""jit'd EmbeddingBag wrapper: padding + backend selection."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref

__all__ = ["embedding_bag"]


def _roundup(x: int, to: int) -> int:
    return -(-x // to) * to


@partial(
    jax.jit,
    static_argnames=("mode", "backend", "rows_per_block", "bag_tile", "interpret"),
)
def embedding_bag(
    table,
    indices,
    weights=None,
    mode: str = "sum",
    backend: str = "xla",
    rows_per_block: int = 4096,
    bag_tile: int = 128,
    interpret: bool = True,
):
    if backend != "pallas":
        return embedding_bag_ref(table, indices, weights, mode=mode)
    V, d = table.shape
    B, L = indices.shape
    if weights is None:
        weights = jnp.ones(indices.shape, table.dtype)
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        weights = weights / denom
    rows_per_block = min(rows_per_block, _roundup(V, 8))
    Vp = _roundup(V, rows_per_block)
    Bp = _roundup(B, min(bag_tile, _roundup(B, 8)))
    bag_tile = min(bag_tile, Bp)
    tbl = jnp.zeros((Vp, d), table.dtype).at[:V].set(table)
    idx = jnp.zeros((Bp, L), indices.dtype).at[:B].set(indices)
    w = jnp.zeros((Bp, L), weights.dtype).at[:B].set(weights)
    out = embedding_bag_pallas(
        tbl, idx, w,
        rows_per_block=rows_per_block, bag_tile=bag_tile, interpret=interpret,
    )
    return out[:B]

"""Pure-jnp EmbeddingBag oracle (gather + weighted reduce).

Also the differentiable path used during training — XLA turns the gather's
VJP into a scatter-add, whose blocked/accumulated variant is exactly the
paper's push-mode TOCAB (see repro.models.bert4rec).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["embedding_bag_ref"]


def embedding_bag_ref(table, indices, weights=None, mode: str = "sum"):
    """table f32[V, d]; indices i32[B, L]; weights f32[B, L] (0 = pad).

    mode ∈ {sum, mean}: mean divides by the weight mass per bag."""
    if weights is None:
        weights = jnp.ones(indices.shape, table.dtype)
    gathered = jnp.take(table, indices, axis=0)  # (B, L, d)
    out = (gathered * weights[..., None]).sum(axis=1)
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out

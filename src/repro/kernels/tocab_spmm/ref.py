"""Pure-jnp oracle for the TOCAB blocked SpMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["tocab_spmm_ref"]


def tocab_spmm_ref(
    values,  # f32[num_blocks*block_size, d]
    window_idx,  # i32[num_blocks, edge_budget]
    compact_idx,  # i32[num_blocks, edge_budget]
    edge_vals,  # f32[num_blocks, edge_budget]
    *,
    block_size: int,
    local_budget: int,
):
    """partials[b, l, :] = Σ_{e: compact_idx[b,e]==l}
    edge_vals[b,e] · values[b·B + window_idx[b,e], :]"""
    num_blocks, edge_budget = window_idx.shape
    src_global = window_idx + (
        jnp.arange(num_blocks, dtype=jnp.int32)[:, None] * block_size
    )
    msgs = values[src_global] * edge_vals[..., None]  # (nb, eb, d)
    flat_idx = (
        compact_idx
        + jnp.arange(num_blocks, dtype=jnp.int32)[:, None] * local_budget
    )
    partials = jax.ops.segment_sum(
        msgs.reshape(-1, values.shape[1]),
        flat_idx.reshape(-1),
        num_segments=num_blocks * local_budget,
    )
    return partials.reshape(num_blocks, local_budget, values.shape[1])

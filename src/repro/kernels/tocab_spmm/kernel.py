"""Pallas TPU kernel for the TOCAB blocked SpMM — the paper's hot loop.

One grid step = one TOCAB subgraph (paper Alg. 4).  The ``BlockSpec`` pins the
block's contiguous source-value window in VMEM — on TPU the residency the
paper gets *probabilistically* from the GPU L2 is *guaranteed* by the DMA
schedule.  Per-edge messages are gathered from the VMEM window and accumulated
into a dense, compacted ``partials`` slab (local-ID compaction), which is
written back as one coalesced burst.  The cross-block reduction (paper
Fig. 5) happens outside the kernel as a flat segment-sum.

Two accumulation regimes (``mode``):

* ``onehot`` — scatter expressed as ``onehotᵀ @ msgs`` small dense matmuls:
  the MXU-native adaptation (irregular traffic → systolic work).  Preferred
  when ``local_budget`` is small relative to the edge chunk.
* ``scatter`` — in-VMEM ``.at[].add`` accumulation (VPU path); preferred for
  very sparse blocks where the one-hot matmul would be mostly zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tocab_spmm_pallas"]

LANE = 128  # TPU lane width; last dims should be multiples of this


def _kernel(
    window_ref,  # (block_size, d)        VMEM — the value window
    widx_ref,  # (1, edge_budget)         VMEM — src index within window
    cidx_ref,  # (1, edge_budget)         VMEM — compacted dst local id
    evals_ref,  # (1, edge_budget)        VMEM — edge values (0 for padding)
    out_ref,  # (1, local_budget, d)      VMEM — dense partial slab
    *,
    chunk: int,
    mode: str,
):
    local_budget = out_ref.shape[1]
    d = out_ref.shape[2]
    edge_budget = widx_ref.shape[1]
    acc = jnp.zeros((local_budget, d), jnp.float32)
    num_chunks = -(-edge_budget // chunk)

    def body(c, acc):
        # Final ragged chunk: clamp the start so the slice stays in bounds,
        # then zero the slots the previous chunk already covered (sum-only
        # kernel, edge values carry the mask — a 0 contribution is a no-op).
        start = jnp.minimum(c * chunk, edge_budget - chunk)
        sl = pl.dslice(start, chunk)
        widx = widx_ref[0, sl]
        cidx = cidx_ref[0, sl]
        ev = evals_ref[0, sl]
        fresh = start + jax.lax.iota(jnp.int32, chunk) >= c * chunk
        ev = jnp.where(fresh, ev, 0.0)
        # gather from the VMEM-resident window (the confined random read)
        msgs = jnp.take(window_ref[...], widx, axis=0) * ev[:, None]
        if mode == "onehot":
            # scatter == one-hot matmul: (local_budget, chunk) @ (chunk, d)
            onehot = (
                cidx[None, :] == jax.lax.iota(jnp.int32, local_budget)[:, None]
            ).astype(jnp.float32)
            acc = acc + jax.lax.dot(
                onehot, msgs, preferred_element_type=jnp.float32
            )
        else:  # scatter (VPU)
            acc = acc.at[cidx].add(msgs)
        return acc

    acc = jax.lax.fori_loop(0, num_chunks, body, acc, unroll=False)
    out_ref[0, :, :] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "local_budget", "chunk", "mode", "interpret"),
)
def tocab_spmm_pallas(
    values,  # f32[num_blocks*block_size, d]  (padded)
    window_idx,  # i32[num_blocks, edge_budget]
    compact_idx,  # i32[num_blocks, edge_budget]
    edge_vals,  # f32[num_blocks, edge_budget] (0 where padded)
    *,
    block_size: int,
    local_budget: int,
    chunk: int = 512,
    mode: str = "onehot",
    interpret: bool = True,
):
    """Phase-2 partials: returns f32[num_blocks, local_budget, d]."""
    num_blocks, edge_budget = window_idx.shape
    d = values.shape[1]
    assert values.shape[0] == num_blocks * block_size, (
        f"values must be padded to num_blocks*block_size, got {values.shape}"
    )
    # ragged edge budgets are fine — the kernel masks the final chunk
    chunk = min(chunk, edge_budget)

    grid = (num_blocks,)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_size, d), lambda b: (b, 0)),  # VMEM window
            pl.BlockSpec((1, edge_budget), lambda b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda b: (b, 0)),
            pl.BlockSpec((1, edge_budget), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, local_budget, d), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, local_budget, d), jnp.float32),
        interpret=interpret,
    )(values, window_idx, compact_idx, edge_vals)

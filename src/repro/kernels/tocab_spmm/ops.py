"""jit'd public wrapper: BlockedGraph → Pallas TOCAB SpMM → global result.

Handles padding (values to num_blocks·block_size rows; feature dim to the
TPU lane width) and runs the phase-3 reduction.  Numerically identical to
``repro.core.tocab.tocab_pull`` (sum semiring) — asserted in tests.

``tocab_spmm_partials`` additionally supports a **bin-aware grid**: pass
``block_ids`` (a static tuple of block indices, e.g. the dense bin of a
``repro.core.balance.BlockSchedule``) and the Pallas grid covers only those
blocks — the sparsity-aware scheduler runs the tile kernel on dense
subgraphs while sparse bins take cheaper segmented-reduce paths.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.partition import BlockedGraph
from repro.core.tocab import reduce_partials
from repro.resilience import chaos as _chaos

from .kernel import LANE, tocab_spmm_pallas
from .ref import tocab_spmm_ref

__all__ = ["tocab_spmm", "tocab_spmm_partials", "LANE"]


def _roundup(x: int, to: int) -> int:
    return -(-x // to) * to


@partial(
    jax.jit,
    static_argnames=(
        "mode", "interpret", "use_ref", "chunk", "block_ids", "unweighted",
        "local_budget",
    ),
)
def tocab_spmm_partials(
    bg: BlockedGraph,
    x: jnp.ndarray,  # f32[n] or f32[n, d]
    mode: str = "onehot",
    chunk: int = 256,
    interpret: bool = True,
    use_ref: bool = False,
    block_ids: Optional[Tuple[int, ...]] = None,
    unweighted: bool = False,
    local_budget: Optional[int] = None,
):
    """Phase-2 partial slabs through the Pallas tile kernel.

    Returns partials of shape ``(k, local_budget)`` (vector ``x``) or
    ``(k, local_budget, d)``, where ``k = len(block_ids)`` (all blocks when
    ``block_ids`` is None, matching the uniform grid).  ``unweighted=True``
    ignores stored edge values (PageRank semantics).  ``local_budget``
    overrides the global partial-slab width — the sparsity-aware scheduler
    passes the dense bin's (much smaller) static row budget, shrinking the
    kernel's one-hot scatter matmul accordingly."""
    _chaos.maybe_raise("kernel.tocab_spmm.op")  # opt-in fault-injection site
    assert bg.direction == "pull"
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    n, d = x.shape
    d_pad = _roundup(d, LANE)
    rows_pad = bg.num_blocks * bg.block_size
    values = jnp.zeros((rows_pad, d_pad), jnp.float32)
    values = values.at[:n, :d].set(x.astype(jnp.float32))

    edge_vals = bg.edge_vals
    if edge_vals is None or unweighted:
        edge_vals = bg.edge_mask.astype(jnp.float32)
    else:
        edge_vals = jnp.where(bg.edge_mask, edge_vals, 0.0)

    window_idx, compact_idx = bg.window_idx, bg.compact_idx
    if block_ids is not None:
        # Bin-aware grid: gather the selected blocks' slabs (and their
        # contiguous value windows) so grid step j maps to block_ids[j].
        ids = jnp.asarray(block_ids, jnp.int32)
        window_idx = jnp.take(window_idx, ids, axis=0)
        compact_idx = jnp.take(compact_idx, ids, axis=0)
        edge_vals = jnp.take(edge_vals, ids, axis=0)
        values = jnp.take(
            values.reshape(bg.num_blocks, bg.block_size, d_pad), ids, axis=0
        ).reshape(len(block_ids) * bg.block_size, d_pad)

    # ragged edge budgets are handled in-kernel (final chunk is masked)
    chunk = max(1, min(chunk, bg.edge_budget))

    fn = tocab_spmm_ref if use_ref else partial(
        tocab_spmm_pallas, chunk=chunk, mode=mode, interpret=interpret
    )
    partials = fn(
        values,
        window_idx,
        compact_idx,
        edge_vals,
        block_size=bg.block_size,
        local_budget=local_budget or bg.local_budget,
    )
    partials = partials[:, :, :d]
    return partials[:, :, 0] if squeeze else partials


@partial(jax.jit, static_argnames=("mode", "interpret", "use_ref", "chunk"))
def tocab_spmm(
    bg: BlockedGraph,
    x: jnp.ndarray,  # f32[n] or f32[n, d]
    mode: str = "onehot",
    chunk: int = 256,
    interpret: bool = True,
    use_ref: bool = False,
):
    """y = Aᵀ-gather-reduce of x through the TOCAB blocked layout.

    ``x`` may be (n,) — SpMV — or (n, d) — SpMM / GNN aggregation.
    Returns the same rank as the input."""
    partials = tocab_spmm_partials(
        bg, x, mode=mode, chunk=chunk, interpret=interpret, use_ref=use_ref
    )
    # partials rank already matches x's rank (vector → (nb, lb)); the phase-3
    # reduction is tail-shape agnostic.
    return reduce_partials(bg, partials, reduce="sum")

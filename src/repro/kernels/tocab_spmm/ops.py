"""jit'd public wrapper: BlockedGraph → Pallas TOCAB SpMM → global result.

Handles padding (values to num_blocks·block_size rows; feature dim to the
TPU lane width) and runs the phase-3 reduction.  Numerically identical to
``repro.core.tocab.tocab_pull`` (sum semiring) — asserted in tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.partition import BlockedGraph
from repro.core.tocab import reduce_partials

from .kernel import LANE, tocab_spmm_pallas
from .ref import tocab_spmm_ref

__all__ = ["tocab_spmm", "LANE"]


def _roundup(x: int, to: int) -> int:
    return -(-x // to) * to


@partial(jax.jit, static_argnames=("mode", "interpret", "use_ref", "chunk"))
def tocab_spmm(
    bg: BlockedGraph,
    x: jnp.ndarray,  # f32[n] or f32[n, d]
    mode: str = "onehot",
    chunk: int = 256,
    interpret: bool = True,
    use_ref: bool = False,
):
    """y = Aᵀ-gather-reduce of x through the TOCAB blocked layout.

    ``x`` may be (n,) — SpMV — or (n, d) — SpMM / GNN aggregation.
    Returns the same rank as the input."""
    assert bg.direction == "pull"
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    n, d = x.shape
    d_pad = _roundup(d, LANE)
    rows_pad = bg.num_blocks * bg.block_size
    values = jnp.zeros((rows_pad, d_pad), jnp.float32)
    values = values.at[:n, :d].set(x.astype(jnp.float32))

    edge_vals = bg.edge_vals
    if edge_vals is None:
        edge_vals = bg.edge_mask.astype(jnp.float32)
    else:
        edge_vals = jnp.where(bg.edge_mask, edge_vals, 0.0)

    chunk = min(chunk, bg.edge_budget)
    # edge_budget is padded to 128; make it divisible by chunk
    while bg.edge_budget % chunk:
        chunk //= 2

    fn = tocab_spmm_ref if use_ref else partial(
        tocab_spmm_pallas, chunk=chunk, mode=mode, interpret=interpret
    )
    partials = fn(
        values,
        bg.window_idx,
        bg.compact_idx,
        edge_vals,
        block_size=bg.block_size,
        local_budget=bg.local_budget,
    )
    out = reduce_partials(bg, partials, reduce="sum")[:, :d]
    return out[:, 0] if squeeze else out

"""Uniform-fanout neighbor sampler (GraphSAGE ``minibatch_lg`` regime).

A *real* sampler per the assignment: layered k-hop uniform sampling from the
CSR in-neighbour lists, producing a static-shape layered subgraph batch
(padded), host-side numpy for throughput + a deterministic seed stream.

Layout of the sampled batch (for ``sample_sizes = (f1, f2)``, 2 layers):
  layer-0 seeds: ``batch_nodes``; layer-1 frontier: batch·f1;
  layer-2 frontier: batch·f1·f2.  Edges connect consecutive layers.
All node ids are *local* to the batch (gathered features), so the model's
static shapes never depend on |V| — this is what makes the huge-graph cell
trainable with a fixed memory budget.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.graph import Graph
from repro.models.gnn import GraphBatch

__all__ = ["NeighborSampler"]


class NeighborSampler:
    def __init__(self, g: Graph, feats: np.ndarray, labels: np.ndarray,
                 sample_sizes=(25, 10), seed: int = 0):
        # in-neighbour CSR (pull direction: aggregate FROM in-neighbours)
        gt = g.transpose()
        self.rowptr = gt.rowptr
        self.colidx = gt.colidx
        self.n = g.n
        self.feats = feats
        self.labels = labels
        self.sizes = tuple(sample_sizes)
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int):
        """For each node, draw ``fanout`` uniform in-neighbours (with
        replacement; isolated nodes self-loop)."""
        lo = self.rowptr[nodes]
        deg = self.rowptr[nodes + 1] - lo
        r = self.rng.integers(0, 2 ** 31, (len(nodes), fanout))
        safe_deg = np.maximum(deg, 1)
        pick = lo[:, None] + (r % safe_deg[:, None])
        nbrs = self.colidx[np.minimum(pick, len(self.colidx) - 1)]
        nbrs = np.where(deg[:, None] > 0, nbrs, nodes[:, None])  # self-loop
        return nbrs.astype(np.int64)

    def sample(self, batch_nodes: int) -> GraphBatch:
        seeds = self.rng.integers(0, self.n, batch_nodes)
        layers = [seeds]
        for f in self.sizes:
            layers.append(self._sample_neighbors(layers[-1], f).reshape(-1))
        # local id space: concatenate all layers (duplicates allowed — the
        # standard layered-SAGE formulation; features gathered per slot)
        all_nodes = np.concatenate(layers)
        offsets = np.cumsum([0] + [len(l) for l in layers])
        srcs, dsts = [], []
        for li, f in enumerate(self.sizes):
            # edges: layer li+1 slot j*f+k  →  layer li slot j
            n_dst = len(layers[li])
            src = offsets[li + 1] + np.arange(n_dst * f)
            dst = offsets[li] + np.repeat(np.arange(n_dst), f)
            srcs.append(src)
            dsts.append(dst)
        src = np.concatenate(srcs).astype(np.int32)
        dst = np.concatenate(dsts).astype(np.int32)
        feats = self.feats[all_nodes]
        labels = self.labels[all_nodes].astype(np.int32)
        node_mask = np.zeros(len(all_nodes), bool)
        node_mask[: batch_nodes] = True  # loss only on seed nodes
        return GraphBatch(
            node_feat=jnp.asarray(feats),
            edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
            edge_mask=jnp.ones(len(src), bool),
            labels=jnp.asarray(labels),
            node_mask=jnp.asarray(node_mask),
        )

    @staticmethod
    def batch_shapes(batch_nodes: int, sizes, d_feat: int):
        """Static shapes of a sampled batch (for input_specs/dry-run)."""
        counts = [batch_nodes]
        for f in sizes:
            counts.append(counts[-1] * f)
        n_nodes = sum(counts)
        n_edges = sum(c * f for c, f in zip(counts[:-1], sizes))
        return n_nodes, n_edges

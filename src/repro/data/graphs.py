"""Synthetic graph datasets matching the assigned GNN shape regimes."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.graph import Graph, rmat_graph, uniform_random_graph
from repro.models.gnn import GraphBatch, build_triplets

__all__ = ["cora_like", "reddit_like", "products_like", "molecule_batch",
           "graph_to_batch"]


def graph_to_batch(g: Graph, d_feat: int, n_classes: int, seed: int = 0,
                   with_positions: bool = False,
                   triplet_cap: int = 8) -> GraphBatch:
    rng = np.random.default_rng(seed)
    src, dst = g.edges()
    feat = rng.standard_normal((g.n, d_feat), dtype=np.float32) * 0.5
    labels = rng.integers(0, n_classes, g.n).astype(np.int32)
    # plant signal: label-dependent feature shift so GNNs can learn
    feat[np.arange(g.n), labels % d_feat] += 2.0
    kwargs = {}
    if with_positions:
        pos = rng.standard_normal((g.n, 3)).astype(np.float32) * 2.0
        kj, ji, tmask = build_triplets(src, dst, g.n, cap_per_edge=triplet_cap)
        kwargs = dict(positions=jnp.asarray(pos), t_kj=jnp.asarray(kj),
                      t_ji=jnp.asarray(ji), t_mask=jnp.asarray(tmask))
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
        edge_mask=jnp.ones(g.m, bool),
        labels=jnp.asarray(labels),
        node_mask=jnp.ones(g.n, bool),
        **kwargs,
    )


def cora_like(n=2708, m=10556, d_feat=1433, n_classes=7, seed=0):
    g = uniform_random_graph(n, m + m // 4, seed=seed)
    return g, graph_to_batch(g, d_feat, n_classes, seed)


def reddit_like(scale=14, edge_factor=16, d_feat=602, n_classes=41, seed=0):
    g = rmat_graph(scale, edge_factor, seed=seed)
    return g, graph_to_batch(g, d_feat, n_classes, seed)


def products_like(scale=15, edge_factor=12, d_feat=100, n_classes=47, seed=0):
    g = rmat_graph(scale, edge_factor, seed=seed)
    return g, graph_to_batch(g, d_feat, n_classes, seed)


def molecule_batch(n_graphs=128, nodes_per=30, d_feat=16, seed=0,
                   cutoff=2.0, triplet_cap=8):
    """Batched small radius-graphs (the DimeNet regime)."""
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per
    pos = rng.random((N, 3)).astype(np.float32) * 3.0
    srcs, dsts = [], []
    for gid in range(n_graphs):
        lo = gid * nodes_per
        p = pos[lo: lo + nodes_per]
        d2 = ((p[:, None] - p[None, :]) ** 2).sum(-1)
        a, b = np.nonzero((d2 < cutoff ** 2) & (d2 > 0))
        srcs.append(a + lo)
        dsts.append(b + lo)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    kj, ji, tmask = build_triplets(src, dst, N, cap_per_edge=triplet_cap)
    feat = rng.standard_normal((N, d_feat), dtype=np.float32)
    # graph-level regression target correlated with mean pairwise distance
    targets = np.array([
        pos[g * nodes_per:(g + 1) * nodes_per].std() for g in range(n_graphs)
    ], np.float32)
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
        edge_mask=jnp.ones(len(src), bool),
        labels=jnp.asarray(targets),
        node_mask=jnp.ones(N, bool),
        positions=jnp.asarray(pos),
        graph_ids=jnp.asarray(np.repeat(np.arange(n_graphs), nodes_per), jnp.int32),
        t_kj=jnp.asarray(kj), t_ji=jnp.asarray(ji), t_mask=jnp.asarray(tmask),
    )

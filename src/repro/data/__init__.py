from .tokens import synthetic_lm_batches  # noqa: F401
from .graphs import cora_like, products_like, reddit_like, molecule_batch  # noqa: F401
from .sampler import NeighborSampler  # noqa: F401
from .recsys import synthetic_recsys_batches  # noqa: F401

"""Synthetic LM token pipeline: deterministic, shardable, prefetch-friendly.

Generates Zipf-distributed token streams with local n-gram structure (so the
loss actually decreases — useful for the convergence examples).  Batches are
placed with the mesh sharding before being handed to the step function.
"""
from __future__ import annotations

from typing import Iterator

import jax
import numpy as np

from repro.dist.sharding import sharding_for

__all__ = ["synthetic_lm_batches"]


def synthetic_lm_batches(
    batch: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    mesh=None,
    grad_accum: int = 0,
) -> Iterator[dict]:
    """Yields {"tokens": (B, S+1)} (or (A, B, S+1) with grad_accum)."""
    rng = np.random.default_rng(seed)
    # fixed bigram table gives the stream learnable structure
    n_ctx = 64
    table = rng.integers(0, vocab, (n_ctx, 8))
    while True:
        shape = (grad_accum, batch) if grad_accum else (batch,)
        state = rng.integers(0, n_ctx, shape)
        toks = np.empty(shape + (seq_len + 1,), np.int32)
        for t in range(seq_len + 1):
            choice = rng.integers(0, 8, shape)
            toks[..., t] = table[state, choice] % vocab
            state = (state * 31 + toks[..., t]) % n_ctx
        out = {"tokens": toks}
        if mesh is not None:
            lead = (None, "batch") if grad_accum else ("batch",)
            out = {
                k: jax.device_put(
                    v, sharding_for(lead + (None,), v.shape, mesh))
                for k, v in out.items()
            }
        yield out

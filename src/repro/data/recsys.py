"""Synthetic BERT4Rec data: Zipf-popularity item sequences + cloze masking."""
from __future__ import annotations

from typing import Iterator

import numpy as np
import jax.numpy as jnp

__all__ = ["synthetic_recsys_batches", "make_cloze_batch"]


def make_cloze_batch(rng, batch: int, seq_len: int, vocab: int,
                     mask_id: int, mask_prob: float = 0.15,
                     step_range: int = 50) -> dict:
    # Zipf-ish popularity with session coherence (random-walk over item
    # space); smaller ``step_range`` → more predictable sessions
    start = rng.zipf(1.3, size=(batch, 1)) % vocab
    steps = rng.integers(-step_range, step_range + 1, (batch, seq_len))
    items = (start + np.cumsum(steps, axis=1)) % vocab
    items = items.astype(np.int32)
    mask = rng.random((batch, seq_len)) < mask_prob
    mask[:, -1] = True  # always predict the final position (next-item eval)
    masked = np.where(mask, mask_id, items)
    return {
        "items": jnp.asarray(masked),
        "labels": jnp.asarray(items),
        "label_mask": jnp.asarray(mask.astype(np.float32)),
    }


def synthetic_recsys_batches(batch: int, seq_len: int, vocab: int,
                             mask_id: int, seed: int = 0,
                             mask_prob: float = 0.15,
                             step_range: int = 50) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        yield make_cloze_batch(rng, batch, seq_len, vocab, mask_id,
                               mask_prob, step_range)

"""Hand-rolled collectives: two-stage distributed top-k, a ppermute ring
all-reduce, and error-feedback-compressed data-parallel gradients.

These are the §Perf mechanisms referenced from the serving path
(``bert4rec_score`` → :func:`distributed_topk`) and the multi-pod training
story (:func:`make_dp_grad_fn` keeps the cross-pod wire format bf16 with an
error-feedback residual so compression noise doesn't accumulate)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "distributed_topk",
    "ring_all_reduce",
    "init_error_feedback",
    "make_dp_grad_fn",
]


def distributed_topk(scores: jnp.ndarray, k: int, mesh: Mesh,
                     axis: str = "model"):
    """Exact two-stage top-k over the vocab/item axis of ``scores`` (B, V).

    Stage 1 takes a local top-k inside each ``axis`` shard (no collective);
    stage 2 reduces the S·k candidates — so the all-gather moves S·k values
    per row instead of V.  Bitwise-identical to ``jax.lax.top_k`` including
    tie-breaking (lower index wins), because per-shard candidates keep index
    order and shards are concatenated in index order."""
    B, V = scores.shape
    shards = dict(mesh.shape).get(axis, 1)
    if shards <= 1 or V % shards:
        return jax.lax.top_k(scores, k)
    v_local = V // shards
    kk = min(k, v_local)
    blocked = scores.reshape(B, shards, v_local)
    loc_v, loc_i = jax.lax.top_k(blocked, kk)  # (B, S, kk)
    offs = (jnp.arange(shards, dtype=jnp.int32) * v_local)[None, :, None]
    cand_v = loc_v.reshape(B, shards * kk)
    cand_i = (loc_i + offs).reshape(B, shards * kk)
    top_v, pos = jax.lax.top_k(cand_v, k)
    top_i = jnp.take_along_axis(cand_i, pos, axis=1)
    return top_v, top_i


def ring_all_reduce(x: jnp.ndarray, axis: str, num_shards: int):
    """Sum all-reduce as ``num_shards - 1`` neighbour ppermutes (the
    bandwidth-optimal ring schedule, unrolled).  shard_map-internal; must
    equal ``lax.psum(x, axis)``."""
    perm = [(i, (i + 1) % num_shards) for i in range(num_shards)]
    acc = x
    for _ in range(num_shards - 1):
        x = jax.lax.ppermute(x, axis, perm)
        acc = acc + x
    return acc


def init_error_feedback(params, num_shards: int):
    """Per-shard fp32 residual tree for compressed gradients (leading axis =
    shard).  Starts at zero: the first step's residual is the bf16 error."""
    return jax.tree.map(
        lambda p: jnp.zeros((num_shards,) + p.shape, jnp.float32), params)


def make_dp_grad_fn(loss_fn: Callable, mesh: Mesh, axis: str,
                    compress: bool = True):
    """Data-parallel gradient fn over mesh ``axis`` with optional bf16
    compression + error feedback.

    Returns ``fn(params, batch, residuals) -> (grads, residuals, loss)``:
    batch and residual leaves carry a leading shard axis sized
    ``mesh.shape[axis]``; grads and loss come back replicated (pmean'd)."""
    num_shards = dict(mesh.shape)[axis]

    def local(params, batch, res):
        mb = jax.tree.map(lambda x: x[0], batch)  # drop the shard axis
        (loss, _aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        loss = jax.lax.pmean(loss, axis)
        if not compress:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            return grads, res, loss
        # error feedback: add the residual before quantizing, keep the
        # quantization error as the next residual (so it is re-sent, not lost)
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r[0], grads, res)
        wire = jax.tree.map(lambda v: v.astype(jnp.bfloat16), corrected)
        new_res = jax.tree.map(
            lambda v, w: (v - w.astype(jnp.float32))[None], corrected, wire)
        grads = jax.tree.map(
            lambda w: jax.lax.pmean(w.astype(jnp.float32), axis), wire)
        return grads, new_res, loss

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axis), P(axis)),
        out_specs=(P(), P(axis), P()),
        check_rep=False,
    )

"""Elastic meshes: build a (data, model) mesh from whatever devices exist
right now, and re-place arrays onto a different mesh (restore-after-resize).

The checkpoint layer is mesh-agnostic (host numpy); elasticity is just
"restore with the new mesh's shardings" — :func:`reshard` is the in-memory
version of the same move."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["make_mesh_for", "reshard"]


def make_mesh_for(num_devices: Optional[int] = None,
                  axes: Sequence[str] = ("data", "model"),
                  model_parallel: int = 1) -> Mesh:
    """Mesh over the first ``num_devices`` devices (default: all).

    ``model_parallel`` is clamped to a divisor of the device count; the
    remainder goes to the data axis — on an elastic resize the same call
    yields the best mesh the surviving devices support."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else min(num_devices, len(devs))
    devs = devs[:n]
    mp = max(1, model_parallel)
    while n % mp:
        mp -= 1
    shape = (n // mp, mp)
    return Mesh(np.array(devs).reshape(shape), tuple(axes))


def reshard(tree, mesh: Mesh, specs=None):
    """Re-place every leaf of ``tree`` onto ``mesh``.

    ``specs`` may be a matching tree of PartitionSpecs, a single spec, or
    None (replicate).  Works across meshes of different sizes — the elastic
    restore path with no disk round-trip."""
    if specs is None or isinstance(specs, P):
        spec = specs if isinstance(specs, P) else P()
        return jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(mesh, spec)), tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)

"""Distribution layer: logical-axis sharding rules, hand-rolled collectives,
and elastic mesh construction.

Everything degrades gracefully to single-device: off-mesh, ``shard`` is the
identity, ``current_mesh()`` is ``None``, and the collectives fall back to
their flat (non-distributed) equivalents.
"""
from . import collectives, elastic, sharding  # noqa: F401

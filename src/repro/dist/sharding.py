"""Logical-axis sharding: a single rules table maps model-level axis names
("batch", "heads", "vocab", ...) onto physical mesh axes ("pod", "data",
"model"), with a divisibility fallback so no shape can ever error.

The pattern follows the t5x/maxtext logical-axis convention: model code
annotates arrays with *logical* names via :func:`shard`; the mapping to the
physical mesh is resolved here, against whatever mesh ``use_mesh_rules``
installed.  Off-mesh (CPU tests, single device) every helper is a no-op, so
the same model code runs unmodified from laptop to pod.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AXIS_RULES",
    "current_mesh",
    "use_mesh_rules",
    "logical_to_spec",
    "sharding_for",
    "shard",
]

# logical axis name → mesh axes tried in order (a tuple entry means "shard
# over the product of these axes together").  First candidate that exists in
# the mesh, has size > 1, and divides the dimension wins; otherwise the
# dimension is replicated (never an error — the divisibility fallback).
AXIS_RULES: dict = {
    # data-parallel-ish dimensions
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "capacity": (("pod", "data"), ("data",), ("pod",)),
    "nodes": (("data",),),
    "edges": (("data",),),
    "candidates": (("data",),),
    "rows": (("data",),),
    # tensor-parallel dimensions
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "mlp": (("model",),),
    "vocab": (("model",),),
    "embed": (("model",),),
    "experts": (("model",),),
    # FSDP: parameters sharded over the data axis
    "fsdp": (("data",),),
    # never sharded (scan axis / sequence kept whole on CPU-scale runs)
    "layers": (),
    "seq": (),
}

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    """The mesh installed by the innermost ``use_mesh_rules`` (or None)."""
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Optional[Mesh]):
    """Install ``mesh`` as the target of the logical-axis rules.

    ``None`` is accepted (single-device runs pass their mesh through
    unconditionally) and makes every sharding helper a no-op."""
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def _mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(mesh.shape)


def logical_to_spec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
) -> P:
    """Resolve logical axis names to a PartitionSpec for ``shape`` on ``mesh``.

    Guarantees: never raises on odd shapes (non-divisible dims fall back to
    replication), never assigns the same mesh axis to two dimensions, drops
    mesh axes of size <= 1."""
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    entries = []
    for name, dim in zip(logical, shape):
        entry = None
        for cand in AXIS_RULES.get(name, ()):
            axes = tuple(a for a in cand
                         if sizes.get(a, 1) > 1 and a not in used)
            if not axes:
                continue
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if dim % prod == 0:
                used.update(axes)
                entry = axes if len(axes) > 1 else axes[0]
                break
        entries.append(entry)
    return P(*entries)


def sharding_for(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Optional[Mesh] = None,
) -> Optional[NamedSharding]:
    """NamedSharding for ``shape`` under the rules (None off-mesh)."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical, shape, mesh))


def shard(x, *logical: Optional[str]):
    """Constrain ``x``'s sharding by logical axis names (identity off-mesh).

    Usable inside jit: resolves against the mesh captured at trace time."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

"""`repro.tune` — cache-model-guided autotuner with a persistent tuning DB.

The stack's performance knobs (TOCAB block size, balanced-schedule bins,
engine variant, Beamer α — the paper's Fig. 11 sensitivity axes) are graph-
and device-dependent; this package searches them per (graph fingerprint,
device kind, dtype) the way XLA/Triton autotune kernels:

* :mod:`repro.tune.space`    — declarative search space (:class:`Candidate`,
  :class:`SearchSpace`, trial budgets);
* :mod:`repro.tune.analytic` — cache-model pre-pass pruning candidates by
  predicted DRAM-per-edge before any timing;
* :mod:`repro.tune.runner`   — empirical trials (warmup + median-of-k via
  ``repro.obs`` spans, everything recorded);
* :mod:`repro.tune.db`       — schema-versioned JSON DB under
  ``experiments/tune/`` with an in-process plan cache;
* :mod:`repro.tune.plan`     — read side: ``schedule="auto"`` resolution
  for the engines, tuned-layout builders for callers that can rebuild;
* :mod:`repro.tune.tuner`    — orchestration; ``python -m repro.tune``
  (``tune`` / ``show`` / ``apply``) is the CLI over the benchmark suite.
"""
from .space import (  # noqa: F401
    BUDGETS,
    Candidate,
    SearchSpace,
    TrialBudget,
    WORKLOADS,
    default_candidate,
)
from .db import DB_SCHEMA, db_path, default_dir, device_key, entry_key  # noqa: F401
from .plan import (  # noqa: F401
    TunedPlan,
    blocked_for,
    resolve_alpha,
    resolve_plan,
    resolve_schedule,
)
from .runner import Trial, run_trial  # noqa: F401
from .tuner import choose, tune, tune_graph  # noqa: F401

"""CLI: ``python -m repro.tune {tune,show,apply} [...]``.

* ``tune``  — search the benchmark graph suite, persist winners to the DB.
* ``show``  — render the DB (one row per entry, chosen config + provenance).
* ``apply`` — print the tuned configuration per graph as ready-to-paste
  ``build_blocked(...)`` / engine kwargs (or ``--json`` for machines).

Examples::

    PYTHONPATH=src python -m repro.tune tune --arch graphcage \\
        --trials-budget small
    PYTHONPATH=src python -m repro.tune show
    PYTHONPATH=src python -m repro.tune apply --graph rmat14
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, Optional

from repro.core.graph import Graph

from . import db as tune_db
from . import tuner
from .space import BUDGETS, WORKLOADS


def _suite_builders() -> dict:
    """The same graph suite ``benchmarks.run`` uses, when the benchmarks
    package is importable (repo checkout); otherwise a built-in equivalent
    (same generators, same seeds) so an installed `repro` still tunes."""
    try:
        from benchmarks.common import SUITE  # type: ignore

        return dict(SUITE)
    except ImportError:
        from repro.core import grid_graph, rmat_graph

        return {
            "rmat14": lambda: rmat_graph(14, 8, seed=1, weights=True),
            "rmat15": lambda: rmat_graph(15, 8, seed=2, weights=True),
            "rmat16": lambda: rmat_graph(16, 8, seed=3, weights=True),
            "grid256": lambda: grid_graph(256, 256),
        }


def _smoke_graphs() -> tuple:
    """Smoke budget tunes only the graph CI smoke jobs already exercise."""
    try:
        from benchmarks.common import SMOKE_GRAPH  # type: ignore

        return (SMOKE_GRAPH,)
    except ImportError:
        return ("rmat14",)


def _load_graphs(names, budget: str) -> Dict[str, Graph]:
    builders = _suite_builders()
    if names:
        unknown = sorted(set(names) - set(builders))
        if unknown:
            raise SystemExit(
                f"unknown graph(s) {unknown}; suite has {sorted(builders)}")
        picked = names
    else:
        picked = _smoke_graphs() if budget == "smoke" else tuple(builders)
    return {n: builders[n]() for n in picked}


def _arch_cfg(arch: str):
    if arch != "graphcage":
        raise SystemExit(f"unknown --arch {arch!r} (only 'graphcage' has "
                         "tunable graph engines)")
    from repro.configs.graphcage import DEFAULT

    return DEFAULT


def _fmt_age(created) -> str:
    try:
        return time.strftime("%Y-%m-%d %H:%M", time.localtime(float(created)))
    except (TypeError, ValueError):
        return "?"


def cmd_tune(args) -> int:
    cfg = _arch_cfg(args.arch)
    budget = args.trials_budget
    graphs = _load_graphs(args.graphs, budget)
    workloads = tuple(args.workloads) if args.workloads else (
        ("pagerank",) if budget == "smoke" else ("pagerank", "spmv"))
    space = None
    if args.impls:
        import dataclasses

        from .space import SearchSpace

        space = dataclasses.replace(
            SearchSpace.for_budget(budget, cfg), impls=tuple(args.impls))
    print(f"# tuning {sorted(graphs)} x {list(workloads)} "
          f"(budget={budget}, dtype={args.dtype}, "
          f"db={tune_db.db_path(args.db_dir)})",
          file=sys.stderr)
    summary = tuner.tune(
        graphs, workloads=workloads, budget=budget, space=space,
        db_dir=args.db_dir, cfg=cfg, force=args.force, verbose=args.verbose,
        dtype=args.dtype, trial_timeout=args.trial_timeout)
    for e in summary["entries"]:
        src = "db-hit" if e.get("db_hit") else (
            f"{len(e['trials'])} trials, {e['pruned_analytic']} pruned")
        star = " *non-default*" if e.get("non_default") else ""
        print(f"{e['graph']}/{e['workload']}: {_chosen_key(e)}"
              f"  ({e['best_us']:.0f}us; {src}){star}")
    print(f"# {len(summary['entries'])} entries, "
          f"{summary['new_trials']} new trials, "
          f"{summary['pruned']} pruned analytically, "
          f"{summary['db_hits']} db hits -> {summary['db_path']}")
    return 0


def _chosen_key(entry: dict) -> str:
    from .space import Candidate

    return Candidate.from_json(entry["chosen"]).key()


def cmd_show(args) -> int:
    d = tune_db.load(tune_db.db_path(args.db_dir))
    entries = d.get("entries", {})
    if not entries:
        print(f"(empty tuning db at {tune_db.db_path(args.db_dir)})")
        return 0
    fp = d.get("fingerprint", {})
    print(f"# {d.get('schema')}  backend={fp.get('backend')} "
          f"device={fp.get('device_kind')} git={fp.get('git_sha')}")
    header = f"{'graph':10} {'workload':9} {'chosen':40} {'us':>9} " \
             f"{'trials':>6} {'pruned':>6} {'created':16}"
    print(header)
    print("-" * len(header))
    for key in sorted(entries):
        e = entries[key]
        print(f"{e.get('graph', '?'):10} {e.get('workload', '?'):9} "
              f"{_chosen_key(e):40} {e.get('best_us', 0):9.0f} "
              f"{len(e.get('trials', [])):6d} "
              f"{e.get('pruned_analytic', 0):6d} "
              f"{_fmt_age(e.get('created')):16}")
    return 0


def cmd_apply(args) -> int:
    d = tune_db.load(tune_db.db_path(args.db_dir))
    entries = [e for e in d.get("entries", {}).values()
               if not args.graph or e.get("graph") == args.graph]
    if not entries:
        print(f"(nothing to apply for "
              f"{args.graph or 'any graph'} in {tune_db.db_path(args.db_dir)})")
        return 1
    if args.json:
        print(json.dumps(
            {f"{e['graph']}/{e['workload']}": e["chosen"] for e in entries},
            indent=1, sort_keys=True))
        return 0
    for e in sorted(entries, key=lambda e: (e["graph"], e["workload"])):
        c = e["chosen"]
        print(f"# {e['graph']} / {e['workload']}  "
              f"({e['best_us']:.0f}us, chosen {_chosen_key(e)})")
        if c["engine"] in ("cb", "tocab"):
            th = c["bin_thresholds"]
            th = tuple(th) if isinstance(th, list) else th
            print(f"bg = build_blocked(g, block_size={c['block_size']}, "
                  f"direction={c['direction']!r}, bin_thresholds={th!r})")
            print(f"out = {'tocab' if c['engine'] == 'tocab' else 'cb'}_"
                  f"{c['direction']}(bg, x"
                  + (f", schedule={c['schedule']!r}"
                     if c["engine"] == "tocab" else "")
                  + (f", impl={c['impl']!r}"
                     if c.get("impl", "slab") != "slab" else "") + ")")
        else:
            print(f"out = baseline_{c['direction']}(dg, x)")
        if e["workload"] == "bfs":
            print(f"depth, *_ = bfs(dg, bg, src, alpha={c['alpha']})")
        print()
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Cache-model-guided autotuner over the benchmark "
                    "graph suite (persistent DB under experiments/tune/).")
    sub = ap.add_subparsers(dest="cmd", required=True)

    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("--db-dir", default=None,
                        help="tuning-db directory (default: $REPRO_TUNE_DIR "
                             "or experiments/tune)")

    t = sub.add_parser("tune", parents=[common],
                       help="search the graph suite, persist winners")
    t.add_argument("--arch", default="graphcage")
    t.add_argument("--trials-budget", default="small",
                   choices=sorted(BUDGETS))
    t.add_argument("--graphs", default=None,
                   type=lambda s: [x for x in s.split(",") if x],
                   help="comma-separated suite graph names "
                        "(default: whole suite; smoke: rmat14)")
    t.add_argument("--workloads", default=None,
                   type=lambda s: [x for x in s.split(",") if x],
                   choices=None, metavar=f"{{{','.join(WORKLOADS)}}}")
    t.add_argument("--impls", default=None,
                   type=lambda s: [x for x in s.split(",") if x],
                   metavar="{slab,fused}",
                   help="restrict the engine-impl axis (default: the "
                        "arch config's tune_impls)")
    t.add_argument("--dtype", default="float32",
                   choices=("float32", "bfloat16"),
                   help="value dtype the trials time and the DB entry is "
                        "keyed on")
    t.add_argument("--force", action="store_true",
                   help="re-tune even on a DB hit")
    t.add_argument("--trial-timeout", default=None, type=float,
                   help="per-candidate wall-clock bound in seconds; a "
                        "candidate that exceeds it is marked poisoned in "
                        "the DB and skipped by later sweeps")
    t.add_argument("--verbose", action="store_true")
    t.set_defaults(fn=cmd_tune)

    s = sub.add_parser("show", parents=[common], help="render the DB")
    s.set_defaults(fn=cmd_show)

    a = sub.add_parser("apply", parents=[common],
                       help="print tuned config per graph")
    a.add_argument("--graph", default=None)
    a.add_argument("--json", action="store_true")
    a.set_defaults(fn=cmd_apply)

    args = ap.parse_args(argv)
    if args.cmd == "tune" and args.workloads:
        bad = sorted(set(args.workloads) - set(WORKLOADS))
        if bad:
            ap.error(f"unknown workload(s) {bad}; expected {WORKLOADS}")
    if args.cmd == "tune" and args.impls:
        bad = sorted(set(args.impls) - {"slab", "fused"})
        if bad:
            ap.error(f"unknown impl(s) {bad}; expected slab/fused")
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())

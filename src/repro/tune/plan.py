"""Plan resolution: map a graph (host, device, or blocked) to its tuned
configuration.

This is the read side of the tuning DB, and the only part of ``repro.tune``
the hot engines touch: ``schedule="auto"`` on ``pagerank`` / ``spmv`` /
``tocab_pull`` / ``tocab_push`` / the traversal kernels calls
:func:`resolve_schedule`, which consults the in-process plan cache, then
the persistent DB, then falls back to the hard-coded defaults.  Resolution
reads only *static* graph metadata (the build-time fingerprint), so it is
safe at jit trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.metrics import registry as _obs

from . import db
from .space import WORKLOADS, Candidate

__all__ = ["TunedPlan", "resolve_plan", "resolve_schedule", "resolve_impl",
           "resolve_alpha", "blocked_for", "clear_cache"]

DEFAULT_ALPHA = 15.0

# (fingerprint, device, dtype, workload) -> Optional[TunedPlan]
# Negative results are cached too: an untuned run must not stat() the DB
# file once per engine call.
_PLANS: dict = {}


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """A resolved DB entry, ready to apply."""

    candidate: Candidate
    workload: str
    graph_fp: str
    source: str  # exact-workload match or borrowed from a sibling workload

    @property
    def schedule(self) -> str:
        return self.candidate.schedule

    @property
    def alpha(self) -> float:
        return self.candidate.alpha

    @property
    def impl(self) -> str:
        return self.candidate.impl


def _fingerprint_of(obj) -> Optional[str]:
    fp = getattr(obj, "fingerprint", None)
    if isinstance(fp, str):
        return fp
    from repro.core.graph import DeviceGraph, Graph, graph_fingerprint

    if isinstance(obj, (Graph, DeviceGraph)):
        return graph_fingerprint(obj)
    return None  # hand-built BlockedGraph without fingerprint: no plan


def resolve_plan(obj, workload: str = "pagerank", dtype: str = "float32",
                 db_dir: Optional[str] = None) -> Optional[TunedPlan]:
    """Tuned plan for ``obj`` (Graph / DeviceGraph / BlockedGraph) or None.

    Prefers an exact-workload entry; otherwise borrows a sibling workload's
    plan for the same graph (a blocked layout tuned for SpMV is a better
    guess for PageRank than the hard-coded default)."""
    fp = _fingerprint_of(obj)
    if fp is None:
        return None
    device = db.device_key()
    # keying on (path, mtime) makes the memo self-invalidating: re-tuning
    # rewrites the file, env-var redirects change the path
    import os

    path = os.path.abspath(db.db_path(db_dir))
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        mtime = 0
    memo_key = (fp, device, dtype, workload, path, mtime)
    if memo_key in _PLANS:
        plan = _PLANS[memo_key]
        _obs.counter("tune.plan_lookups", "schedule=auto resolutions").inc(
            result="memory" if plan else "miss", workload=workload)
        return plan
    entries = db.load(path).get("entries", {})
    plan = None
    for wl in (workload, *[w for w in WORKLOADS if w != workload]):
        entry = entries.get(db.entry_key(fp, device, dtype, wl))
        if entry is not None:
            plan = TunedPlan(
                candidate=Candidate.from_json(entry["chosen"]),
                workload=workload, graph_fp=fp,
                source="db" if wl == workload else f"db:{wl}")
            break
    _PLANS[memo_key] = plan
    _obs.counter("tune.plan_lookups", "schedule=auto resolutions").inc(
        result=plan.source if plan else "miss", workload=workload)
    return plan


def resolve_schedule(obj, workload: str = "pagerank",
                     dtype: str = "float32",
                     db_dir: Optional[str] = None) -> str:
    """Concrete ``schedule`` for ``schedule="auto"``: the plan's choice when
    its engine family is blocked, else ``uniform``.  A plan whose winner is
    a *flat* engine pins ``uniform`` — the caller already committed to a
    blocked engine, and the balanced dispatch only pays when tuning said
    so."""
    plan = resolve_plan(obj, workload=workload, dtype=dtype, db_dir=db_dir)
    if plan is None or not plan.candidate.blocked:
        return "uniform"
    return plan.candidate.schedule


def resolve_impl(obj, workload: str = "pagerank", dtype: str = "float32",
                 db_dir: Optional[str] = None) -> str:
    """Concrete ``impl`` for ``impl="auto"``: the plan's slab/fused pick for
    a blocked winner, else ``slab``.  Entries written before the impl axis
    existed deserialize with the ``slab`` default, so old DBs stay valid."""
    plan = resolve_plan(obj, workload=workload, dtype=dtype, db_dir=db_dir)
    if plan is None or not plan.candidate.blocked:
        return "slab"
    return plan.candidate.impl


def resolve_alpha(obj, workload: str = "bfs", dtype: str = "float32",
                  db_dir: Optional[str] = None,
                  default: float = DEFAULT_ALPHA) -> float:
    """Tuned Beamer α for traversal, falling back to the paper's 15."""
    plan = resolve_plan(obj, workload=workload, dtype=dtype, db_dir=db_dir)
    return default if plan is None else plan.alpha


def blocked_for(g, workload: str = "pagerank", dtype: str = "float32",
                db_dir: Optional[str] = None, direction: Optional[str] = None):
    """Build a :class:`~repro.core.partition.BlockedGraph` per the tuned
    plan (block size + bin thresholds), defaulting to the stock
    ``build_blocked`` when untuned — the `apply` path for callers that can
    rebuild their layout."""
    from repro.core.partition import build_blocked

    plan = resolve_plan(g, workload=workload, dtype=dtype, db_dir=db_dir)
    if plan is None or not plan.candidate.blocked:
        return build_blocked(g, direction=direction or "pull")
    c = plan.candidate
    return build_blocked(
        g, block_size=c.block_size, direction=direction or c.direction,
        bin_thresholds=c.bin_thresholds)


def clear_cache():
    """Drop memoized plans (tests, or after re-tuning in-process)."""
    _PLANS.clear()
    db.clear_cache()

"""Persistent tuning database: schema-versioned JSON under
``experiments/tune/`` plus an in-process plan cache.

One file (``TUNE_DB.json``) holds every tuned entry, keyed by
``graph-fingerprint / device-kind / dtype / workload`` — the same identity
axes XLA's autotuning cache uses.  Writes go through
:func:`repro.obs.export.write_json` (atomic replace) and carry the run
fingerprint, so a CI-cached DB can be told apart from one tuned on
different hardware.

The DB is a *cache, not a source of truth* — so IO hardening is allowed to
be lossy in one direction only: a file that can't be parsed (corrupt JSON,
wrong schema) is **quarantined** to ``TUNE_DB.json.corrupt-<ts>`` and the
DB rebuilt empty (``tune.db_recovered{reason}`` counter); a *transient*
read fault (disk hiccup, injected chaos) is retried and, on exhaustion,
served as an empty DB for that call — the on-disk file is left untouched
so good data is never destroyed by a passing fault.  A separate top-level
``poisoned`` table records tuner candidates that crashed or timed out, so
later sweeps skip them (:func:`mark_poisoned` / :func:`poisoned_for`).
"""
from __future__ import annotations

import os
import time
from typing import Optional

import jax

from repro.obs import export as obs_export
from repro.obs.metrics import registry as _obs
from repro.resilience import chaos as _chaos
from repro.resilience.retry import Policy

__all__ = [
    "DB_SCHEMA",
    "DB_FILENAME",
    "default_dir",
    "db_path",
    "device_key",
    "entry_key",
    "load",
    "save",
    "get_entry",
    "put_entry",
    "mark_poisoned",
    "poisoned_for",
    "clear_cache",
]

#: retry policy for DB IO — transient faults only; parse errors are not
#: retried (they quarantine instead).
IO_POLICY = Policy(max_attempts=3, base_delay=0.02,
                   retry_on=(OSError, _chaos.ChaosError))

#: bump on any incompatible change to the TUNE_DB.json layout
DB_SCHEMA = "repro.tune.db/v1"
DB_FILENAME = "TUNE_DB.json"

# (abspath -> (mtime, db dict)) — the in-process cache; schedule="auto"
# resolution must not re-read the file per engine call.
_CACHE: dict = {}


def default_dir() -> str:
    """DB directory: ``$REPRO_TUNE_DIR`` or ``experiments/tune`` (cwd)."""
    return os.environ.get("REPRO_TUNE_DIR") or os.path.join(
        "experiments", "tune")


def db_path(db_dir: Optional[str] = None) -> str:
    return os.path.join(db_dir or default_dir(), DB_FILENAME)


def device_key() -> str:
    """Device identity half of the entry key (spaces sanitized)."""
    devs = jax.devices()
    kind = devs[0].device_kind if devs else "none"
    return str(kind).strip().replace(" ", "-").lower()


def entry_key(graph_fp: str, device: Optional[str] = None,
              dtype: str = "float32", workload: str = "pagerank") -> str:
    return f"{graph_fp}/{device or device_key()}/{dtype}/{workload}"


def _empty() -> dict:
    return obs_export.versioned_payload(DB_SCHEMA, "tune_db", entries={})


def _mtime(path: str) -> int:
    try:
        return os.stat(path).st_mtime_ns
    except OSError:
        return -1


def _quarantine(path: str, reason: str) -> Optional[str]:
    """Move an unusable DB file aside and count the recovery.  Returns the
    quarantine path (None if the move itself failed)."""
    qpath = f"{path}.corrupt-{int(time.time())}"
    try:
        os.replace(path, qpath)
    except OSError:
        qpath = None
    _CACHE.pop(path, None)
    _obs.counter(
        "tune.db_recovered",
        "tuning-db files recovered by quarantine-and-rebuild",
    ).inc(reason=reason)
    return qpath


def _read(path: str) -> dict:
    _chaos.maybe_raise("tune.db_load")
    return obs_export.read_json(path)


def load(path: Optional[str] = None, use_cache: bool = True) -> dict:
    """Read the DB (empty shell if the file doesn't exist).  Cached by
    (path, mtime): touching the file invalidates, in-process writers update
    the cache themselves via :func:`save`.

    Never raises on a bad file: corrupt JSON or a wrong schema quarantines
    the file (``TUNE_DB.json.corrupt-<ts>``) and returns an empty DB; a
    transient read fault is retried and on exhaustion returns an empty DB
    *without* touching the file."""
    path = os.path.abspath(path or db_path())
    mtime = _mtime(path)
    if use_cache:
        hit = _CACHE.get(path)
        if hit is not None and hit[0] == mtime:
            _obs.counter("tune.db_reads", "tuning-db loads").inc(source="cache")
            return hit[1]
    if mtime == -1:
        return _empty()
    try:
        db = IO_POLICY.call(_read, path, site="tune.db_load")
    except (OSError, _chaos.ChaosError):
        # Transient IO exhausted its retries: the file may be fine — serve
        # empty for this call, leave the data alone.
        _obs.counter(
            "tune.db_recovered",
            "tuning-db files recovered by quarantine-and-rebuild",
        ).inc(reason="io")
        return _empty()
    except ValueError:  # unparsable JSON — genuinely corrupt
        _quarantine(path, "corrupt")
        return _empty()
    if not isinstance(db, dict) or db.get("schema") != DB_SCHEMA:
        _quarantine(path, "schema")
        return _empty()
    _CACHE[path] = (mtime, db)
    _obs.counter("tune.db_reads", "tuning-db loads").inc(source="disk")
    return db


def _write(path: str, db: dict):
    _chaos.maybe_raise("tune.db_save")
    obs_export.write_json(path, db)


def save(db: dict, path: Optional[str] = None) -> str:
    path = os.path.abspath(path or db_path())
    IO_POLICY.call(_write, path, db, site="tune.db_save")
    _CACHE[path] = (_mtime(path), db)
    _obs.counter("tune.db_writes", "tuning-db saves").inc()
    return path


def get_entry(key: str, path: Optional[str] = None) -> Optional[dict]:
    return load(path).get("entries", {}).get(key)


def _save_best_effort(db: dict, path: str):
    """Persist, degrading to the in-process cache when the disk write fails
    (retries exhausted) — callers in a sweep keep seeing the new data and
    the next successful save flushes it."""
    try:
        save(db, path)
    except Exception as e:
        _CACHE[path] = (_mtime(path), db)
        _obs.counter(
            "tune.db_save_failed",
            "tuning-db saves degraded to in-process cache only",
        ).inc(error=type(e).__name__)


def put_entry(key: str, entry: dict, path: Optional[str] = None,
              persist: bool = True) -> dict:
    """Insert/replace one entry (stamped with key + creation time) and, by
    default, persist immediately — a crashed sweep keeps finished work.
    A failed disk write degrades to the in-process cache (counted) rather
    than aborting the sweep."""
    path = os.path.abspath(path or db_path())
    db = load(path)
    entry = dict(entry, key=key, created=entry.get("created") or time.time())
    db.setdefault("entries", {})[key] = entry
    if persist:
        _save_best_effort(db, path)
    return entry


def mark_poisoned(key: str, cand_key: str, error: str,
                  path: Optional[str] = None) -> dict:
    """Record a tuner candidate that crashed or timed out for ``key`` so
    later sweeps skip it without re-running the failure."""
    path = os.path.abspath(path or db_path())
    db = load(path)
    rec = {"error": error, "ts": time.time()}
    db.setdefault("poisoned", {}).setdefault(key, {})[cand_key] = rec
    _save_best_effort(db, path)
    _obs.counter(
        "tune.poisoned", "tuner candidates marked poisoned"
    ).inc(key=key)
    return rec


def poisoned_for(key: str, path: Optional[str] = None) -> dict:
    """``{candidate key -> record}`` of poisoned candidates for ``key``."""
    return load(path).get("poisoned", {}).get(key, {})


def clear_cache():
    """Drop the in-process DB cache (tests / cross-process refresh)."""
    _CACHE.clear()

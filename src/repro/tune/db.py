"""Persistent tuning database: schema-versioned JSON under
``experiments/tune/`` plus an in-process plan cache.

One file (``TUNE_DB.json``) holds every tuned entry, keyed by
``graph-fingerprint / device-kind / dtype / workload`` — the same identity
axes XLA's autotuning cache uses.  Writes go through
:func:`repro.obs.export.write_json` (atomic replace) and carry the run
fingerprint, so a CI-cached DB can be told apart from one tuned on
different hardware.
"""
from __future__ import annotations

import os
import time
from typing import Optional

import jax

from repro.obs import export as obs_export
from repro.obs.metrics import registry as _obs

__all__ = [
    "DB_SCHEMA",
    "DB_FILENAME",
    "default_dir",
    "db_path",
    "device_key",
    "entry_key",
    "load",
    "save",
    "get_entry",
    "put_entry",
    "clear_cache",
]

#: bump on any incompatible change to the TUNE_DB.json layout
DB_SCHEMA = "repro.tune.db/v1"
DB_FILENAME = "TUNE_DB.json"

# (abspath -> (mtime, db dict)) — the in-process cache; schedule="auto"
# resolution must not re-read the file per engine call.
_CACHE: dict = {}


def default_dir() -> str:
    """DB directory: ``$REPRO_TUNE_DIR`` or ``experiments/tune`` (cwd)."""
    return os.environ.get("REPRO_TUNE_DIR") or os.path.join(
        "experiments", "tune")


def db_path(db_dir: Optional[str] = None) -> str:
    return os.path.join(db_dir or default_dir(), DB_FILENAME)


def device_key() -> str:
    """Device identity half of the entry key (spaces sanitized)."""
    devs = jax.devices()
    kind = devs[0].device_kind if devs else "none"
    return str(kind).strip().replace(" ", "-").lower()


def entry_key(graph_fp: str, device: Optional[str] = None,
              dtype: str = "float32", workload: str = "pagerank") -> str:
    return f"{graph_fp}/{device or device_key()}/{dtype}/{workload}"


def _empty() -> dict:
    return obs_export.versioned_payload(DB_SCHEMA, "tune_db", entries={})


def load(path: Optional[str] = None, use_cache: bool = True) -> dict:
    """Read the DB (empty shell if the file doesn't exist).  Cached by
    (path, mtime): touching the file invalidates, in-process writers update
    the cache themselves via :func:`save`."""
    path = os.path.abspath(path or db_path())
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return _empty()
    if use_cache:
        hit = _CACHE.get(path)
        if hit is not None and hit[0] == mtime:
            _obs.counter("tune.db_reads", "tuning-db loads").inc(source="cache")
            return hit[1]
    db = obs_export.read_json(path)
    if db.get("schema") != DB_SCHEMA:
        raise ValueError(
            f"{path}: schema {db.get('schema')!r} != {DB_SCHEMA!r} — "
            "delete or re-tune (the DB is a cache, not a source of truth)")
    _CACHE[path] = (mtime, db)
    _obs.counter("tune.db_reads", "tuning-db loads").inc(source="disk")
    return db


def save(db: dict, path: Optional[str] = None) -> str:
    path = os.path.abspath(path or db_path())
    obs_export.write_json(path, db)
    _CACHE[path] = (os.stat(path).st_mtime_ns, db)
    _obs.counter("tune.db_writes", "tuning-db saves").inc()
    return path


def get_entry(key: str, path: Optional[str] = None) -> Optional[dict]:
    return load(path).get("entries", {}).get(key)


def put_entry(key: str, entry: dict, path: Optional[str] = None,
              persist: bool = True) -> dict:
    """Insert/replace one entry (stamped with key + creation time) and, by
    default, persist immediately — a crashed sweep keeps finished work."""
    path = os.path.abspath(path or db_path())
    db = load(path)
    entry = dict(entry, key=key, created=entry.get("created") or time.time())
    db.setdefault("entries", {})[key] = entry
    if persist:
        save(db, path)
    return entry


def clear_cache():
    """Drop the in-process DB cache (tests / cross-process refresh)."""
    _CACHE.clear()

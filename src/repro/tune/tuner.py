"""Tuner orchestration: search space → analytic prune → trials → DB entry.

``tune_graph`` is the unit of work (one graph × one workload); ``tune``
sweeps a suite and returns a summary whose ``new_trials`` count lets CI
(and the acceptance test) assert that a second run is served entirely from
the persistent DB.
"""
from __future__ import annotations

import sys
from typing import Dict, Optional

from repro.core.graph import Graph, graph_fingerprint
from repro.obs.metrics import registry as _obs

from . import analytic, db, runner
from .space import BUDGETS, Candidate, SearchSpace, TrialBudget, default_candidate

__all__ = ["tune_graph", "tune", "choose"]


def choose(trials: list) -> Optional[runner.Trial]:
    """Winner = lowest median; deterministic tie-break on the candidate key
    so re-runs of an identical sweep pick the identical config."""
    if not trials:
        return None
    return min(trials, key=lambda t: (t.us, t.candidate.key()))


def _record_chosen(entry: dict, graph_name: str):
    """Tuner decision → obs registry (satellite: `repro.obs.report` can
    show trials run / pruned counts / the chosen config as a labeled
    gauge)."""
    c = entry["chosen"]
    _obs.gauge(
        "tune.chosen", "chosen tuner config (value = median µs)",
    ).set(entry["best_us"], graph=graph_name, workload=entry["workload"],
          engine=c["engine"], direction=c["direction"],
          schedule=c["schedule"], impl=c.get("impl", "slab"),
          block_size=c["block_size"])
    _obs.gauge("tune.chosen_block_size", "tuned TOCAB block size").set(
        c["block_size"], graph=graph_name, workload=entry["workload"])
    _obs.gauge("tune.non_default", "1 when tuning beat the hard-coded "
               "default config").set(
        float(entry["non_default"]), graph=graph_name,
        workload=entry["workload"])


def tune_graph(
    g: Graph,
    graph_name: str,
    workload: str = "pagerank",
    space: Optional[SearchSpace] = None,
    budget: TrialBudget = BUDGETS["small"],
    db_dir: Optional[str] = None,
    dtype: str = "float32",
    force: bool = False,
    default: Optional[Candidate] = None,
    verbose: bool = False,
    trial_timeout: Optional[float] = None,
) -> dict:
    """Tune one (graph, workload); returns the DB entry (existing one on a
    DB hit).  The entry records every trial, the analytic prune, and the
    chosen candidate.

    A candidate that crashes or exceeds ``trial_timeout`` seconds is marked
    *poisoned* in the DB — later sweeps (force or not) skip it upfront
    instead of re-running a known failure."""
    path = db.db_path(db_dir)
    fp = graph_fingerprint(g)
    key = db.entry_key(fp, dtype=dtype, workload=workload)
    if not force:
        hit = db.get_entry(key, path)
        if hit is not None:
            _obs.counter("tune.db_hits", "tune requests served from the "
                         "persistent DB").inc(workload=workload)
            return dict(hit, db_hit=True)

    space = space or SearchSpace()
    cands = space.candidates(workload)
    kept, pruned = analytic.prune(
        g, cands, prune_ratio=budget.prune_ratio,
        graph_name=graph_name, workload=workload)
    kept = kept[: budget.max_trials]
    poisoned = db.poisoned_for(key, path)
    poisoned_skipped = [c.key() for c in kept if c.key() in poisoned]
    if poisoned_skipped:
        kept = [c for c in kept if c.key() not in poisoned]
        _obs.counter(
            "tune.poisoned_skipped",
            "poisoned candidates skipped before trials",
        ).inc(len(poisoned_skipped), workload=workload)
    trials, skipped = [], []
    for c in kept:
        try:
            trials.append(runner.run_trial(
                g, c, workload=workload, budget=budget,
                graph_name=graph_name, dtype=dtype,
                timeout=trial_timeout))
            if verbose:
                print(f"#   trial {graph_name}/{workload} {c.key()}: "
                      f"{trials[-1].us:.0f}us", file=sys.stderr)
        except Exception as e:  # unusable combo, crash, or timeout
            skipped.append({"candidate": c.to_json(), "error": repr(e)})
            _obs.counter("tune.trials_skipped",
                         "candidates that failed to run").inc(
                workload=workload)
            db.mark_poisoned(key, c.key(), repr(e), path)
    best = choose(trials)
    if best is None:
        raise RuntimeError(
            f"no runnable candidate for {graph_name}/{workload} "
            f"({len(pruned)} pruned, {len(skipped)} failed)")
    default = default or default_candidate()
    entry = {
        "schema": db.DB_SCHEMA,
        "graph": graph_name,
        "graph_fp": fp,
        "device_kind": db.device_key(),
        "dtype": dtype,
        "workload": workload,
        "budget": budget.name,
        "chosen": best.candidate.to_json(),
        "best_us": best.us,
        "non_default": best.candidate != default,
        "candidates": len(cands),
        "pruned_analytic": len(pruned),
        "trials": [t.to_json() for t in trials],
        "skipped": skipped,
        "poisoned_skipped": poisoned_skipped,
    }
    db.put_entry(key, entry, path)
    _record_chosen(entry, graph_name)
    return dict(entry, db_hit=False)


def tune(
    graphs: Dict[str, Graph],
    workloads=("pagerank", "spmv"),
    budget: str = "small",
    space: Optional[SearchSpace] = None,
    db_dir: Optional[str] = None,
    cfg=None,
    force: bool = False,
    verbose: bool = False,
    dtype: str = "float32",
    trial_timeout: Optional[float] = None,
) -> dict:
    """Sweep a graph suite; returns a summary dict:

    ``{"entries": [...], "new_trials": N, "pruned": N, "db_hits": N}``.
    ``dtype`` keys the DB entries *and* the value arrays the trials time."""
    tb = BUDGETS[budget] if isinstance(budget, str) else budget
    space = space or SearchSpace.for_budget(tb.name, cfg)
    default = default_candidate(getattr(cfg, "block_size", 2048))
    entries, new_trials, pruned, db_hits = [], 0, 0, 0
    for gname, g in graphs.items():
        for wl in workloads:
            entry = tune_graph(
                g, gname, workload=wl, space=space, budget=tb,
                db_dir=db_dir, force=force, default=default,
                verbose=verbose, dtype=dtype, trial_timeout=trial_timeout)
            entries.append(entry)
            if entry.get("db_hit"):
                db_hits += 1
            else:
                new_trials += len(entry["trials"])
                pruned += entry["pruned_analytic"]
    return {"entries": entries, "new_trials": new_trials,
            "pruned": pruned, "db_hits": db_hits,
            "db_path": db.db_path(db_dir)}

"""Declarative search space over TOCAB execution parameters.

A :class:`Candidate` is one fully-specified engine configuration — the
product of the axes the paper identifies as performance-critical:

* ``engine``      — ``base`` (flat), ``cb`` (blocked, no compaction) or
  ``tocab`` (blocked + compacted), × ``direction`` pull/push;
* ``block_size``  — the Fig. 11 subgraph size (the fast-memory window);
* ``schedule``    — uniform vs sparsity-aware balanced dispatch, and for
  balanced runs the ``dense_impl`` (Pallas tile kernel on/off) and the
  edges-per-row ``bin_thresholds``;
* ``alpha``       — the Beamer direction-switch constant (traversal only).

:class:`SearchSpace` enumerates only *valid* combinations per workload
(``cb`` has no push or balanced variant, traversal's blocked phase is pull
only, ...), so the analytic pre-pass and trial runner never waste time on
configurations the engines would reject.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Tuple, Union

from repro.core.partition import DEFAULT_BIN_THRESHOLDS

__all__ = [
    "Candidate",
    "SearchSpace",
    "TrialBudget",
    "BUDGETS",
    "WORKLOADS",
    "default_candidate",
]

#: workloads the trial runner knows how to time
WORKLOADS = ("pagerank", "spmv", "bfs")

Thresholds = Union[Tuple[float, float], str]


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space (hashable, JSON round-trippable)."""

    engine: str = "tocab"  # base | cb | tocab
    direction: str = "pull"  # pull | push
    schedule: str = "uniform"  # uniform | balanced
    dense_impl: Optional[str] = None  # pallas | onehot | None (backend pick)
    impl: str = "slab"  # slab | fused (tocab engines only)
    block_size: int = 2048
    bin_thresholds: Thresholds = DEFAULT_BIN_THRESHOLDS
    alpha: float = 15.0  # Beamer direction-switch constant (traversal)

    @property
    def blocked(self) -> bool:
        return self.engine in ("cb", "tocab")

    def key(self) -> str:
        """Short canonical label (benchmark record / obs series name)."""
        parts = [self.engine]
        if self.blocked:
            parts += [self.direction, f"b{self.block_size}", self.schedule]
            if self.impl != "slab":
                parts.append(self.impl)
            if self.schedule == "balanced":
                parts.append(self.dense_impl or "autoimpl")
                th = self.bin_thresholds
                parts.append(th if isinstance(th, str)
                             else f"t{th[0]:g}-{th[1]:g}")
        if self.alpha != 15.0:
            parts.append(f"a{self.alpha:g}")
        return "/".join(parts)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if isinstance(d["bin_thresholds"], tuple):
            d["bin_thresholds"] = list(d["bin_thresholds"])
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Candidate":
        d = dict(d)
        th = d.get("bin_thresholds")
        if isinstance(th, list):
            d["bin_thresholds"] = tuple(th)
        return cls(**{k: v for k, v in d.items()
                      if k in {f.name for f in dataclasses.fields(cls)}})


def default_candidate(block_size: int = 2048) -> Candidate:
    """The configuration the stack hard-codes today — the tuner's baseline
    for the "picked a non-default config" signal."""
    return Candidate(engine="tocab", direction="pull", schedule="uniform",
                     block_size=block_size)


@dataclasses.dataclass(frozen=True)
class TrialBudget:
    """Empirical-measurement budget for one ``tune`` invocation."""

    name: str
    warmup: int
    reps: int
    #: analytic pre-pass keeps (engine, block) groups whose predicted
    #: DRAM-per-edge is within this factor of the best prediction
    prune_ratio: float
    #: hard cap on empirical trials per (graph, workload)
    max_trials: int


BUDGETS = {
    "smoke": TrialBudget("smoke", warmup=1, reps=1, prune_ratio=1.25,
                         max_trials=6),
    "small": TrialBudget("small", warmup=1, reps=3, prune_ratio=2.0,
                         max_trials=24),
    "full": TrialBudget("full", warmup=2, reps=5, prune_ratio=4.0,
                        max_trials=96),
}


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Axis lists; :meth:`candidates` takes their valid product."""

    engines: Tuple[str, ...] = ("base", "cb", "tocab")
    directions: Tuple[str, ...] = ("pull", "push")
    schedules: Tuple[str, ...] = ("uniform", "balanced")
    dense_impls: Tuple[Optional[str], ...] = (None,)
    impls: Tuple[str, ...] = ("slab", "fused")
    block_sizes: Tuple[int, ...] = (1024, 2048, 8192)
    bin_thresholds: Tuple[Thresholds, ...] = (DEFAULT_BIN_THRESHOLDS,)
    alphas: Tuple[float, ...] = (15.0,)

    def candidates(self, workload: str = "pagerank") -> list:
        """Valid candidates for ``workload``, deterministic order.

        Traversal (``bfs``) explores α and restricts the blocked phase to
        pull (the sparse phase is always flat push); ``cb`` exists only as
        the paper's pull strawman; ``balanced``/``dense_impl``/thresholds
        only apply to TOCAB engines."""
        if workload not in WORKLOADS:
            raise ValueError(f"unknown workload {workload!r}; "
                             f"expected one of {WORKLOADS}")
        alphas = self.alphas if workload == "bfs" else (15.0,)
        out = []
        for engine, alpha in itertools.product(self.engines, alphas):
            if engine == "base":
                dirs = self.directions if workload != "bfs" else ("pull",)
                for d in dirs:
                    out.append(Candidate(engine="base", direction=d,
                                         alpha=alpha))
                continue
            if engine == "cb" and workload == "bfs":
                continue  # traversal's blocked phase is TOCAB-or-flat
            dirs = ("pull",) if (engine == "cb" or workload == "bfs") \
                else self.directions
            for direction, bs in itertools.product(dirs, self.block_sizes):
                scheds = ("uniform",) if engine == "cb" else self.schedules
                for sched in scheds:
                    if sched != "balanced":
                        # fused is a TOCAB-only uniform-schedule variant
                        impls = self.impls if engine == "tocab" \
                            else ("slab",)
                        for impl in impls:
                            out.append(Candidate(
                                engine=engine, direction=direction,
                                schedule=sched, impl=impl, block_size=bs,
                                alpha=alpha))
                        continue
                    for impl, th in itertools.product(
                            self.dense_impls, self.bin_thresholds):
                        out.append(Candidate(
                            engine=engine, direction=direction,
                            schedule="balanced", dense_impl=impl,
                            block_size=bs, bin_thresholds=th, alpha=alpha))
        # dedup while preserving order (axes may coincide, e.g. base×alpha)
        seen, uniq = set(), []
        for c in out:
            if c not in seen:
                seen.add(c)
                uniq.append(c)
        return uniq

    @classmethod
    def for_budget(cls, budget: str, cfg=None) -> "SearchSpace":
        """Budget presets, seeded from :class:`~repro.configs.graphcage.
        GraphCageCfg` when given (its block/α defaults stay in the space so
        the tuner can *confirm* the hard-coded choice, not just replace it).
        """
        block = getattr(cfg, "block_size", 8192)
        alpha = getattr(cfg, "bfs_alpha", 15.0)
        blocks = set(getattr(cfg, "tune_block_sizes",
                             (1024, 2048, 4096, 8192, 16384))) | {block}
        alphas = set(getattr(cfg, "tune_alphas", (4.0, 64.0))) | {alpha}
        impls = tuple(getattr(cfg, "tune_impls", ("slab", "fused")))
        if budget == "smoke":
            return cls(engines=("base", "tocab"), directions=("pull",),
                       block_sizes=(2048,), impls=impls, alphas=(alpha,))
        if budget == "small":
            return cls(block_sizes=tuple(sorted({1024, 2048, block})),
                       impls=impls, alphas=tuple(sorted(alphas)))
        if budget == "full":
            return cls(
                block_sizes=tuple(sorted(blocks | {512})),
                dense_impls=(None, "onehot", "pallas"),
                impls=impls,
                bin_thresholds=(DEFAULT_BIN_THRESHOLDS, "auto"),
                alphas=tuple(sorted(alphas | {2.0})))
        raise ValueError(
            f"unknown budget {budget!r}; expected one of {sorted(BUDGETS)}")

"""Analytic pre-pass: prune the search space with the cache model before
any timing.

The paper's whole argument is that DRAM transactions per edge (Fig. 10)
predict wall clock; ``repro.core.cache_model`` replays the exact access
stream of each engine family.  Candidates only differ in their *stream* by
(engine, block_size) — schedule/dense-impl/α reshuffle the same accesses —
so we score each (engine, block_size) group once, keep groups whose
predicted DRAM-per-edge is within ``prune_ratio`` of the best, and hand
only the survivors to the empirical trial runner.
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

from repro.core.cache_model import CacheConfig, simulate_pagerank_variant
from repro.core.graph import Graph, graph_fingerprint
from repro.obs.metrics import registry as _obs

from .space import Candidate

__all__ = ["MODEL_CFG", "predicted_cost", "prune", "clear_cache"]

#: scaled LLC for the CPU-scale suite — same |V|·4B / capacity ratio the
#: fig9/fig10 benchmarks use for the paper's LiveJournal / 2.75 MB pairing
MODEL_CFG = CacheConfig(capacity_bytes=64 * 1024, line_bytes=128, ways=16)

# cache-model variant per engine family (push shares base's stream shape;
# tocab-push shares tocab's blocked one)
_MODEL_VARIANT = {"base": "base", "cb": "cb", "tocab": "tocab"}


def _variant_of(candidate: Candidate) -> str:
    """tocab × impl='fused' replays the no-partial-slab stream; everything
    else keys on engine alone."""
    if candidate.engine == "tocab" and candidate.impl == "fused":
        return "fused"
    return _MODEL_VARIANT[candidate.engine]


def _group_of(candidate: Candidate) -> tuple:
    """Stream-equivalence group: schedule/dense-impl/α don't change the
    access stream, but the fused impl does."""
    if not candidate.blocked:
        return (candidate.engine, "slab", 0)
    impl = candidate.impl if candidate.engine == "tocab" else "slab"
    return (candidate.engine, impl, candidate.block_size)

# (graph_fp, variant, block_size, cfg) -> replay result dict.  The LRU
# replay is a host-side Python loop over every edge — worth memoizing hard.
_MEMO: dict = {}


def predicted_cost(g: Graph, candidate: Candidate,
                   cfg: CacheConfig = MODEL_CFG) -> dict:
    """Cache-model replay for ``candidate``'s stream group (memoized)."""
    variant = _variant_of(candidate)
    block = candidate.block_size if candidate.blocked else 0
    key = (graph_fingerprint(g), variant, block, cfg)
    if key not in _MEMO:
        _MEMO[key] = simulate_pagerank_variant(
            g, variant, cfg, block_size=block or None)
        _obs.counter("tune.analytic_replays",
                     "cache-model replays run by the tuner").inc(
            variant=variant)
    return _MEMO[key]


def prune(g: Graph, candidates: Iterable[Candidate],
          prune_ratio: float = 2.0,
          cfg: CacheConfig = MODEL_CFG,
          graph_name: Optional[str] = None,
          workload: str = "pagerank") -> Tuple[list, list]:
    """Split candidates into (kept, pruned) by predicted DRAM-per-edge.

    Returns candidates in their original order; every candidate gains no
    state — the caller reads per-group scores from the obs registry
    (``tune.analytic_dram_per_edge``) or via :func:`predicted_cost`."""
    candidates = list(candidates)
    if not candidates:
        return [], []
    scores = {}
    for c in candidates:
        group = _group_of(c)
        if group not in scores:
            scores[group] = predicted_cost(g, c, cfg)["dram_per_edge"]
    best = min(scores.values())
    cut = best * max(prune_ratio, 1.0)
    kept, pruned = [], []
    for c in candidates:
        (kept if scores[_group_of(c)] <= cut else pruned).append(c)
    labels = dict(workload=workload)
    if graph_name:
        labels["graph"] = graph_name
    for (engine, impl, block), s in sorted(scores.items()):
        _obs.gauge(
            "tune.analytic_dram_per_edge",
            "cache-model prediction per candidate stream group",
        ).set(s, engine=engine, impl=impl, block_size=block, **labels)
    _obs.counter("tune.candidates_pruned",
                 "candidates dropped by the analytic pre-pass").inc(
        len(pruned), **labels)
    _obs.counter("tune.candidates_kept",
                 "candidates surviving the analytic pre-pass").inc(
        len(kept), **labels)
    return kept, pruned


def clear_cache():
    _MEMO.clear()

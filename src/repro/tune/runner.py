"""Empirical trial runner: time surviving candidates, record everything,
pick the winner.

All timing flows through ``repro.obs`` spans (``tune.trial`` spans with
``Span.block`` attributing device wait) — no ad-hoc ``time.perf_counter``
bookkeeping — so trials land in the same registry/trace stream as every
other hot path and export with benchmark artifacts.  Blocked graphs are
built once per (graph, direction, block_size, thresholds) and shared
across candidates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# NB: import the submodules explicitly — ``repro.core`` re-exports the
# ``spmv`` *function*, which shadows the submodule attribute of the package
from repro.core.spmv import spmv as _spmv_fn
from repro.core import traversal as _traversal
from repro.core.graph import DeviceGraph, Graph, graph_fingerprint
from repro.core.pagerank import pagerank_iteration
from repro.core.partition import build_blocked
from repro.obs import trace as obs_trace
from repro.obs.metrics import registry as _obs
from repro.resilience import chaos as _chaos
from repro.resilience.retry import call_with_timeout

from .space import Candidate, TrialBudget

__all__ = ["Trial", "run_trial", "time_fn", "build_for", "clear_cache"]

# (graph_fp, direction, block_size, thresholds) -> BlockedGraph
_BG_MEMO: dict = {}
# graph_fp -> DeviceGraph
_DG_MEMO: dict = {}


@dataclasses.dataclass(frozen=True)
class Trial:
    """One timed candidate (JSON round-trippable via ``to_json``)."""

    candidate: Candidate
    us: float  # median wall-clock per call, microseconds
    reps: int
    warmup: int
    workload: str
    edges_per_s: float

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["candidate"] = self.candidate.to_json()
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Trial":
        d = dict(d)
        d["candidate"] = Candidate.from_json(d["candidate"])
        return cls(**{k: v for k, v in d.items()
                      if k in {f.name for f in dataclasses.fields(cls)}})


def clear_cache():
    _BG_MEMO.clear()
    _DG_MEMO.clear()


def build_for(g: Graph, candidate: Candidate):
    """(DeviceGraph, BlockedGraph-or-None) for one candidate, memoized."""
    fp = graph_fingerprint(g)
    dg = _DG_MEMO.get(fp)
    if dg is None:
        dg = _DG_MEMO[fp] = DeviceGraph.from_host(g)
    if not candidate.blocked:
        return dg, None
    key = (fp, candidate.direction, candidate.block_size,
           candidate.bin_thresholds)
    bg = _BG_MEMO.get(key)
    if bg is None:
        bg = _BG_MEMO[key] = build_blocked(
            g, block_size=candidate.block_size,
            direction=candidate.direction,
            bin_thresholds=candidate.bin_thresholds)
    return dg, bg


def _pr_variant(candidate: Candidate) -> str:
    if candidate.engine == "base":
        return "base" if candidate.direction == "pull" else "push"
    if candidate.engine == "cb":
        return "cb"
    return "gc-pull" if candidate.direction == "pull" else "gc-push"


def _workload_fn(workload: str, g: Graph, dg, bg, candidate: Candidate,
                 dtype: str = "float32"):
    """Jitted callable + args for one (workload, candidate, dtype) pairing.

    ``dtype`` is the value dtype the trial times (the DB entry's key dtype)
    — a bfloat16-keyed entry must be tuned on bfloat16 streams, not assume
    float32."""
    vdtype = jnp.dtype(dtype)
    if workload == "pagerank":
        rank = jnp.full((g.n,), 1.0 / g.n, vdtype)
        variant = _pr_variant(candidate)
        fn = jax.jit(lambda r: pagerank_iteration(
            variant, dg, bg, r, dg.out_degree,
            schedule=candidate.schedule, impl=candidate.impl))
        return fn, (rank,)
    if workload == "spmv":
        x = jnp.ones((g.n,), vdtype)
        variant = _pr_variant(candidate)
        fn = jax.jit(lambda xx: _spmv_fn(
            dg, bg, xx, variant=variant, schedule=candidate.schedule,
            dense_impl=candidate.dense_impl, impl=candidate.impl))
        return fn, (x,)
    if workload == "bfs":
        fn = jax.jit(lambda s: _traversal.bfs(
            dg, bg, s, alpha=candidate.alpha,
            schedule=candidate.schedule, impl=candidate.impl))
        return fn, (jnp.int32(0),)
    raise ValueError(f"unknown workload {workload!r}")


def time_fn(fn, args: Tuple, warmup: int, reps: int, **span_attrs) -> float:
    """Median wall-clock (µs) over ``reps`` measured calls, each one a
    ``tune.trial`` obs span with the device wait blocked inside it."""
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    durs = []
    for rep in range(max(reps, 1)):
        with obs_trace.span("tune.trial", rep=rep, **span_attrs) as sp:
            sp.block(fn(*args))
        durs.append(sp.dur_s)
    durs.sort()
    return durs[len(durs) // 2] * 1e6


def run_trial(g: Graph, candidate: Candidate, workload: str = "pagerank",
              budget: Optional[TrialBudget] = None,
              graph_name: Optional[str] = None,
              warmup: int = 1, reps: int = 3,
              dtype: str = "float32",
              timeout: Optional[float] = None) -> Trial:
    """Build, time, and record one candidate.

    Engines with unusable combinations surface as exceptions — the sweep
    in ``repro.tune.tuner`` converts those into skipped trials and marks
    the candidate poisoned.  ``timeout`` (seconds) bounds the whole
    build+compile+measure of this candidate (a hung compile raises
    ``TimeoutError`` instead of wedging the sweep); ``tune.trial`` is an
    opt-in chaos site."""
    _chaos.maybe_raise("tune.trial")
    if budget is not None:
        warmup, reps = budget.warmup, budget.reps

    def _measure():
        dg, bg = build_for(g, candidate)
        fn, args = _workload_fn(workload, g, dg, bg, candidate, dtype)
        return time_fn(fn, args, warmup, reps,
                       workload=workload, candidate=candidate.key(),
                       graph=graph_name or graph_fingerprint(g))

    us = call_with_timeout(_measure, timeout)
    eps = g.m / max(us * 1e-6, 1e-12)
    labels = dict(workload=workload, candidate=candidate.key())
    if graph_name:
        labels["graph"] = graph_name
    _obs.counter("tune.trials", "empirical tuner trials run").inc(
        workload=workload, **({"graph": graph_name} if graph_name else {}))
    _obs.histogram("tune.trial_us", "tuner trial medians").observe(
        us, **labels)
    _obs.gauge("tune.trial_edges_per_s", "tuner trial throughput").set(
        eps, **labels)
    return Trial(candidate=candidate, us=us, reps=reps, warmup=warmup,
                 workload=workload, edges_per_s=eps)

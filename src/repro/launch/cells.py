"""Dry-run cell builders: (arch × shape) → (step_fn, arg specs, model FLOPs).

Everything is built with ``jax.eval_shape`` + ``ShapeDtypeStruct`` — no
device allocation ever happens for the full-size configs (assignment rule:
FULL configs are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, ShapeCell, get_arch
from repro.dist.sharding import logical_to_spec, sharding_for
from repro.models import transformer as tfm
from repro.models import bert4rec as b4r
from repro.models.gnn import GNNConfig, GraphBatch, gnn_loss_fn
from repro.train.optim import adamw, constant_schedule
from repro.train.trainer import make_train_step

__all__ = ["Cell", "build_cell", "arg_bytes_per_device"]

KEY = jax.random.PRNGKey(0)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable  # to be jitted + lowered with ``args``
    args: tuple  # ShapeDtypeStructs (sharding-annotated)
    model_flops: float
    tokens_or_items: float = 0.0
    description: str = ""


def _sds(shape, dtype, logical, mesh) -> jax.ShapeDtypeStruct:
    sh = sharding_for(logical, shape, mesh)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def _annotate_tree(shapes_tree, logical_tree, mesh):
    """Attach NamedShardings to a tree of ShapeDtypeStructs."""
    def one(axes, s):
        spec = logical_to_spec(axes, s.shape, mesh)
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree.map(
        one, logical_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )


def _match_opt_shardings(opt_shapes, params_ann, mesh):
    """Give optimizer-state leaves the sharding of the same-shaped param
    (Adam moments mirror params exactly); others replicated."""
    by_shape = {}
    for leaf in jax.tree.leaves(params_ann):
        by_shape.setdefault((leaf.shape, str(leaf.dtype)), leaf.sharding)

    def one(s):
        sh = by_shape.get((s.shape, str(s.dtype)))
        if sh is None:
            sh = NamedSharding(mesh, P())
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)

    return jax.tree.map(one, opt_shapes)


def _optimizer():
    return adamw(constant_schedule(1e-4), weight_decay=0.0)


# --------------------------------------------------------------------- #
# LM cells
# --------------------------------------------------------------------- #
def _lm_param_specs(cfg, mesh):
    shapes = jax.eval_shape(lambda k: tfm.init_params(cfg, k), KEY)
    return _annotate_tree(shapes, tfm.param_logical_axes(cfg), mesh)


def _lm_train_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg = spec.make_model_cfg()
    params = _lm_param_specs(cfg, mesh)
    opt = _optimizer()
    opt_shapes = jax.eval_shape(opt.init, params)
    opt_ann = _match_opt_shardings(opt_shapes, params, mesh)
    B, S = cell.global_batch, cell.seq_len
    batch = {"tokens": _sds((B, S + 1), jnp.int32, ("batch", None), mesh)}
    step = make_train_step(lambda p, b: tfm.loss_fn(p, b, cfg), opt)
    tokens = B * S
    flops = 6.0 * cfg.active_param_count() * tokens
    return Cell(spec.arch_id, cell.name, "train", step,
                (params, opt_ann, batch), flops, tokens,
                f"train_step {cfg.name} B={B} S={S}")


def _lm_prefill_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg = spec.make_model_cfg()
    params = _lm_param_specs(cfg, mesh)
    B, S = cell.global_batch, cell.seq_len
    tokens_spec = _sds((B, S), jnp.int32, ("batch", None), mesh)
    fn = partial(tfm.serve_prefill, cfg=cfg)
    flops = 2.0 * cfg.active_param_count() * B * S
    return Cell(spec.arch_id, cell.name, "prefill", fn,
                (params, tokens_spec), flops, B * S,
                f"serve_prefill {cfg.name} B={B} S={S}")


def _lm_decode_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg = spec.make_model_cfg()
    params = _lm_param_specs(cfg, mesh)
    B, S = cell.global_batch, cell.seq_len
    cache_shapes = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, horizon=S))
    cache_logical = jax.tree.map(
        lambda s: ("layers", "batch", "kv_heads", "seq", None), cache_shapes)
    cache = _annotate_tree(cache_shapes, cache_logical, mesh)
    token = _sds((B, 1), jnp.int32, ("batch", None), mesh)
    pos = _sds((), jnp.int32, (), mesh)
    fn = partial(tfm.serve_decode, cfg=cfg)
    # per-step flops: params matmuls + attention against live KV
    if cfg.layer_pattern == "window":
        s_eff = min(cfg.window, S) * cfg.n_layers
    elif cfg.layer_pattern == "alternating":
        s_eff = (min(cfg.window, S) + S) * cfg.n_layers // 2
    else:
        s_eff = S * cfg.n_layers
    attn_flops = 4.0 * B * cfg.n_heads * cfg.head_dim * s_eff
    flops = 2.0 * cfg.active_param_count() * B + attn_flops
    return Cell(spec.arch_id, cell.name, "decode", fn,
                (params, token, pos, cache), flops, B,
                f"serve_decode {cfg.name} B={B} KV={S}")


# --------------------------------------------------------------------- #
# GNN cells
# --------------------------------------------------------------------- #
def _gnn_batch_specs(cfg: GNNConfig, cell: ShapeCell, mesh,
                     triplet_cap: int = 8) -> GraphBatch:
    if cell.kind == "gnn_minibatch":
        counts = [cell.batch_nodes]
        for f in cell.fanout:
            counts.append(counts[-1] * f)
        N = sum(counts)
        E = sum(c * f for c, f in zip(counts[:-1], cell.fanout))
        graph_level = False
        G = 0
    elif cell.kind == "gnn_molecule":
        N = cell.n_graphs * cell.nodes_per_graph
        E = cell.n_graphs * cell.edges_per_graph
        graph_level = True
        G = cell.n_graphs
    else:  # gnn_full
        N, E = cell.n_nodes, cell.n_edges
        graph_level = False
        G = 0
    # §Perf: pad node/edge counts to a mesh-friendly multiple — odd counts
    # (ogb_products: N=2,449,029, E=61,859,140) otherwise force the whole
    # edge pipeline to replicate (divisibility fallback), costing ~16× on
    # the memory term.  Padded slots are masked (edge_mask/node_mask).
    N = -(-N // 512) * 512
    E = -(-E // 512) * 512
    d = cell.d_feat
    need_geo = cfg.arch == "dimenet"
    T = -(-(E * triplet_cap) // 128) * 128 if need_geo else 0
    mk = lambda shape, dt, ax: _sds(shape, dt, ax, mesh)
    kwargs = {}
    if need_geo:
        kwargs.update(
            positions=mk((N, 3), jnp.float32, ("nodes", None)),
            t_kj=mk((T,), jnp.int32, ("edges",)),
            t_ji=mk((T,), jnp.int32, ("edges",)),
            t_mask=mk((T,), jnp.bool_, ("edges",)),
        )
    if graph_level or need_geo:
        kwargs.setdefault("graph_ids",
                          mk((N,), jnp.int32, ("nodes",)))
    labels = (mk((G,), jnp.float32, (None,)) if (graph_level and need_geo)
              else mk((G,), jnp.int32, (None,)) if graph_level
              else mk((N,), jnp.int32, ("nodes",)))
    return GraphBatch(
        node_feat=mk((N, d), jnp.float32, ("nodes", None)),
        edge_src=mk((E,), jnp.int32, ("edges",)),
        edge_dst=mk((E,), jnp.int32, ("edges",)),
        edge_mask=mk((E,), jnp.bool_, ("edges",)),
        labels=labels,
        node_mask=mk((N,), jnp.bool_, ("nodes",)),
        **kwargs,
    ), N, E, (T if need_geo else 0)


def _gnn_flops(cfg: GNNConfig, N, E, T, d_in) -> float:
    d = cfg.d_hidden
    if cfg.arch == "gat":
        per_layer = 2 * N * d_in * cfg.n_heads * d + 2 * E * cfg.n_heads * d * 2
        return float(cfg.n_layers * per_layer) * 3  # fwd+bwd
    if cfg.arch == "gin":
        per_layer = 2 * N * (d_in * d + d * d) + E * d
        return float(cfg.n_layers * per_layer) * 3
    if cfg.arch == "sage":
        per_layer = 2 * N * d_in * d * 2 + E * d
        return float(cfg.n_layers * per_layer) * 3
    # dimenet: triplet bilinear dominates
    per_block = 2 * E * d * d + 2 * T * cfg.n_bilinear + 2 * E * cfg.n_bilinear * d
    return float(cfg.n_blocks * per_block + 2 * N * d_in * d) * 3


def _gnn_train_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    base = spec.make_model_cfg()
    graph_level = cell.kind == "gnn_molecule"
    cfg = dataclasses.replace(
        base, d_in=cell.d_feat, graph_level=graph_level,
        n_classes=(1 if (base.arch == "dimenet" and graph_level)
                   else base.n_classes))
    batch, N, E, T = _gnn_batch_specs(cfg, cell, mesh)
    from repro.models.gnn import init_gnn
    params_shapes = jax.eval_shape(lambda k: init_gnn(k, cfg), KEY)
    # GNN params are small → replicate
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=NamedSharding(mesh, P())),
        params_shapes)
    opt = _optimizer()
    opt_ann = _match_opt_shardings(jax.eval_shape(opt.init, params), params, mesh)
    step = make_train_step(lambda p, b: gnn_loss_fn(p, b, cfg), opt)
    flops = _gnn_flops(cfg, N, E, T, cell.d_feat)
    return Cell(spec.arch_id, cell.name, "gnn_train", step,
                (params, opt_ann, batch), flops, E,
                f"gnn train {cfg.arch} N={N} E={E} T={T}")


# --------------------------------------------------------------------- #
# RecSys cells
# --------------------------------------------------------------------- #
def _recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg = spec.make_model_cfg()
    params_shapes = jax.eval_shape(lambda k: b4r.init_bert4rec(cfg, k), KEY)
    logical = jax.tree.map(lambda s: (None,) * s.ndim, params_shapes)
    logical["item_emb"] = ("rows", None)  # shard the huge table
    params = _annotate_tree(params_shapes, logical, mesh)
    L = cfg.max_len
    d = cfg.d_model
    backbone = cfg.n_blocks * (4 * d * d + 2 * d * cfg.d_ff_mult * d)
    if cell.kind == "recsys_train":
        B, M, K = cell.batch, cfg.max_masked, cfg.num_negatives
        batch = {
            "items": _sds((B, L), jnp.int32, ("batch", None), mesh),
            "mask_pos": _sds((B, M), jnp.int32, ("batch", None), mesh),
            "pos_labels": _sds((B, M), jnp.int32, ("batch", None), mesh),
            "pos_weight": _sds((B, M), jnp.float32, ("batch", None), mesh),
            "negatives": _sds((K,), jnp.int32, (None,), mesh),
        }
        opt = _optimizer()
        opt_ann = _match_opt_shardings(
            jax.eval_shape(opt.init, params), params, mesh)
        step = make_train_step(lambda p, b: b4r.bert4rec_loss_fn(p, b, cfg), opt)
        flops = 6.0 * backbone * B * L + 6.0 * B * M * (K + 1) * d
        return Cell(spec.arch_id, cell.name, "recsys_train", step,
                    (params, opt_ann, batch), flops, B,
                    f"bert4rec train B={B} L={L} sampled_softmax")
    if cell.kind == "recsys_serve":
        B = cell.batch
        items = _sds((B, L), jnp.int32, ("batch", None), mesh)
        fn = partial(b4r.bert4rec_score, cfg=cfg)
        flops = 2.0 * backbone * B * L + 2.0 * B * cfg.vocab * d
        return Cell(spec.arch_id, cell.name, "recsys_serve", fn,
                    (params, items), flops, B,
                    f"bert4rec score B={B} V={cfg.vocab}")
    # retrieval
    B, C = cell.batch, cell.n_candidates
    items = _sds((B, L), jnp.int32, (None, None), mesh)
    cands = _sds((C,), jnp.int32, ("candidates",), mesh)
    fn = partial(b4r.bert4rec_retrieve, cfg=cfg)
    flops = 2.0 * backbone * B * L + 2.0 * C * d
    return Cell(spec.arch_id, cell.name, "recsys_retrieval", fn,
                (params, items, cands), flops, C,
                f"bert4rec retrieve C={C}")


# --------------------------------------------------------------------- #
def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               overrides: Optional[dict] = None) -> Cell:
    """``overrides`` are dataclasses.replace'd into the model config —
    used by the roofline pass (use_scan=False) and the §Perf hillclimb
    (remat/sharding/dtype variants)."""
    spec = get_arch(arch_id)
    if overrides:
        base_make = spec.make_model_cfg
        spec = dataclasses.replace(
            spec, make_model_cfg=lambda: dataclasses.replace(
                base_make(), **overrides))
    cell = next(c for c in spec.shapes if c.name == shape_name)
    if spec.family == "lm":
        if cell.kind == "train":
            return _lm_train_cell(spec, cell, mesh)
        if cell.kind == "prefill":
            return _lm_prefill_cell(spec, cell, mesh)
        return _lm_decode_cell(spec, cell, mesh)
    if spec.family == "gnn":
        return _gnn_train_cell(spec, cell, mesh)
    return _recsys_cell(spec, cell, mesh)


def arg_bytes_per_device(args, num_devices: int) -> float:
    """Resident argument bytes per device implied by the arg shardings."""
    total = 0.0
    for leaf in jax.tree.leaves(args):
        nbytes = float(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None and getattr(sh, "spec", None) is not None:
            mesh = sh.mesh
            denom = 1
            for ax in jax.tree.leaves(tuple(sh.spec)):
                if ax is not None:
                    denom *= dict(mesh.shape)[ax]
            total += nbytes / denom
        else:
            total += nbytes
    return total

"""Serving launcher: batched-request loop for the LM (decode w/ KV cache)
or recsys (catalogue scoring) families.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
        [--requests 16] [--max-new 32]

Uses smoke configs on CPU (the full configs are dry-run territory); the
serving loop itself — prefill, ring-buffer KV caches, batched decode —
is the production code path lowered in the decode_* cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.obs import trace as obs_trace
from repro.obs.metrics import registry as _obs
from repro.resilience import chaos as _chaos
from repro.resilience.retry import Policy

#: per-batch retry: the serving steps are pure functions of their inputs
#: (cache in → cache out), so re-running a failed batch is idempotent.
BATCH_POLICY = Policy(max_attempts=3, base_delay=0.02)


def _resilient_step(fn, *args):
    """One serving batch step behind the retry policy; ``serve.batch`` is a
    chaos site, so fault-injection runs exercise the retry path."""

    def _once():
        _chaos.maybe_raise("serve.batch")
        return fn(*args)

    return BATCH_POLICY.call(_once, site="serve.batch")


def serve_lm(spec, args):
    from repro.models import transformer as tfm
    cfg = spec.make_smoke_cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B = args.requests
    horizon = args.prompt_len + args.max_new
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt_len)),
                          jnp.int32)

    # prefill: run the forward over the prompt, fill the cache by decoding
    # prompt tokens (didactic CPU path; real serving fuses this)
    cache = tfm.init_cache(cfg, B, horizon)
    decode = jax.jit(
        lambda p, t, pos, c: tfm.serve_decode(p, t, pos, c, cfg))
    tok = prompts[:, :1]
    t0 = time.perf_counter()
    with obs_trace.span("serve.prefill", requests=B,
                        prompt_len=args.prompt_len) as sp:
        for t in range(args.prompt_len - 1):
            _, cache = _resilient_step(
                decode, params, prompts[:, t:t + 1], jnp.int32(t), cache)
        sp.block(cache)
    t_prefill = time.perf_counter() - t0
    _obs.histogram("serve.prefill_seconds",
                   "prompt prefill walltime per batch").observe(t_prefill)
    generated = []
    tok = prompts[:, -1:]
    t1 = time.perf_counter()
    with obs_trace.span("serve.decode", requests=B,
                        max_new=args.max_new) as sp:
        for t in range(args.prompt_len - 1, args.prompt_len + args.max_new - 1):
            td = time.perf_counter()
            logits, cache = _resilient_step(
                decode, params, tok, jnp.int32(t), cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            jax.block_until_ready(tok)
            _obs.histogram("serve.decode_seconds",
                           "per-token decode step walltime").observe(
                time.perf_counter() - td)
            generated.append(tok)
        sp.block(tok)
    t_decode = time.perf_counter() - t1
    dt = time.perf_counter() - t0
    total_tokens = B * (args.prompt_len + args.max_new)
    _obs.gauge("serve.tokens_per_s", "end-to-end serving throughput").set(
        total_tokens / max(dt, 1e-9))
    _obs.gauge("serve.decode_tokens_per_s", "decode-phase throughput").set(
        B * args.max_new / max(t_decode, 1e-9))
    print(f"{B} requests × ({args.prompt_len} prompt + {args.max_new} new) "
          f"in {dt:.2f}s → {total_tokens/dt:.0f} tok/s (greedy)")
    out = jnp.concatenate(generated, axis=1)
    print("sample continuation (request 0):", np.asarray(out[0])[:16])


def serve_recsys(spec, args):
    import dataclasses
    from repro.models.bert4rec import bert4rec_score, init_bert4rec
    cfg = dataclasses.replace(spec.make_smoke_cfg(), vocab=5000)
    params = init_bert4rec(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.integers(0, cfg.vocab,
                                     (args.requests, cfg.max_len)), jnp.int32)
    fn = jax.jit(lambda p, i: bert4rec_score(p, i, cfg, top_k=10))
    vals, idx = fn(params, items)
    t0 = time.perf_counter()
    reps = 20
    score_hist = _obs.histogram("serve.score_seconds",
                                "recsys catalogue-scoring walltime per batch")
    with obs_trace.span("serve.score", requests=args.requests, reps=reps):
        for _ in range(reps):
            tr = time.perf_counter()
            vals, idx = _resilient_step(fn, params, items)
            jax.block_until_ready(vals)
            score_hist.observe(time.perf_counter() - tr)
    dt = (time.perf_counter() - t0) / reps
    _obs.gauge("serve.users_per_s", "recsys scoring throughput").set(
        args.requests / max(dt, 1e-9))
    print(f"scored {args.requests} users × {cfg.vocab} items → top-10 in "
          f"{dt*1e3:.1f} ms/batch ({args.requests/dt:.0f} users/s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    spec = get_arch(args.arch)
    if spec.family == "lm":
        serve_lm(spec, args)
    elif spec.family == "recsys":
        serve_recsys(spec, args)
    else:
        raise SystemExit(f"{args.arch} ({spec.family}) has no serving mode")


if __name__ == "__main__":
    main()

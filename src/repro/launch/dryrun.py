"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The ``os.environ`` line below MUST stay the first statement — jax locks the
device count on first init, and the production meshes need 512 host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell this emits JSON with memory_analysis, cost_analysis and the
collective schedule parsed from the post-SPMD HLO (§Roofline inputs).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax

from repro.configs import all_cells, get_arch
from repro.dist.sharding import use_mesh_rules
from repro.launch.cells import arg_bytes_per_device, build_cell
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _cost_get(cost, key: str) -> float:
    if isinstance(cost, list):  # jax < 0.5 returns [dict]
        cost = cost[0] if cost else {}
    if not cost:
        return 0.0
    return float(cost.get(key, 0.0))


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR, save_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    rec = dict(arch=arch_id, shape=shape_name, mesh=mesh_name,
               num_devices=int(n_dev), ok=False)
    try:
        with use_mesh_rules(mesh):
            cell = build_cell(arch_id, shape_name, mesh)
            rec["description"] = cell.description
            rec["model_flops"] = cell.model_flops
            lowered = jax.jit(cell.fn).lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
        except Exception:
            mem = None
        hlo = compiled.as_text()
        coll = parse_collectives(hlo, n_dev)
        # cost_analysis on the SPMD-partitioned module reports per-partition
        # numbers; scale to whole-program totals for the roofline.
        flops_total = _cost_get(cost, "flops") * n_dev
        bytes_total = _cost_get(cost, "bytes accessed") * n_dev
        rl = roofline_terms(flops_total, bytes_total, coll, n_dev,
                            model_flops=cell.model_flops)
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=_cost_get(cost, "flops"),
            bytes_per_device=_cost_get(cost, "bytes accessed"),
            arg_bytes_per_device=arg_bytes_per_device(cell.args, n_dev),
            memory_analysis=(str(mem) if mem is not None else None),
            hlo_ops=hlo.count("\n"),
            **{k: (v if not isinstance(v, dict) else v)
               for k, v in rl.items()},
        )
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir, f"{arch_id}__{shape_name}__{mesh_name}.hlo"),
                    "w") as f:
                f.write(hlo)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch_id, cell, _ in all_cells():
            cells.append((arch_id, cell.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = 0
    for arch_id, shape_name in cells:
        spec = get_arch(arch_id)
        if shape_name in spec.skip_shapes:
            print(f"SKIP {arch_id} × {shape_name} (per DESIGN.md)")
            continue
        rec = run_cell(arch_id, shape_name, args.multi_pod, args.out,
                       args.save_hlo)
        if rec["ok"]:
            n_ok += 1
            print(f"OK   {arch_id} × {shape_name} [{rec['mesh']}] "
                  f"compile={rec['compile_s']}s "
                  f"dom={rec['dominant']} bound={rec['bound_seconds']:.3e}s "
                  f"args/dev={rec['arg_bytes_per_device']/2**30:.2f}GiB")
        else:
            print(f"FAIL {arch_id} × {shape_name} [{rec['mesh']}]: "
                  f"{rec['error']}")
    print(f"\n{n_ok}/{len(cells)} cells compiled")


if __name__ == "__main__":
    main()

"""Roofline-term extraction from a compiled dry-run artifact.

``cost_analysis`` gives HLO FLOPs and bytes; collective bytes are NOT in
cost_analysis, so we parse the post-SPMD HLO text and sum wire bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, using ring-algorithm per-device wire formulas.

Hardware constants (assignment): TPU v5e-like — 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HW", "CollectiveStats", "parse_collectives", "roofline_terms"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

HW = dict(peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, ici_bw=ICI_BW)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict  # raw tensor bytes (outputs)
    wire_bytes: float  # per-device ring-model wire traffic (sum over ops)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _shapes_bytes(text: str) -> int:
    """Sum bytes of all shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    counts = {k: 0 for k in _COLL_KINDS}
    bytes_by_kind = {k: 0.0 for k in _COLL_KINDS}
    wire = 0.0
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        # match `%name = <shape(s)> <op>(` — op name right before '('
        m = re.search(r"=\s+(\([^)]*\)|[a-z0-9\[\],{}\s]*?)\s*"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        if kind + "-done(" in line:
            continue  # avoid double counting start/done pairs
        size = _shapes_bytes(shape_txt)
        g = _group_size(line, num_devices)
        counts[kind] += 1
        bytes_by_kind[kind] += size
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            wire += 2.0 * size * frac  # reduce-scatter + all-gather ring
        elif kind == "all-gather":
            wire += size * frac  # size = full output
        elif kind == "reduce-scatter":
            wire += size * g * frac  # size = scattered output; input = g×
        elif kind == "all-to-all":
            wire += size * frac
        else:  # collective-permute
            wire += size
    return CollectiveStats(counts=counts, bytes_by_kind=bytes_by_kind,
                           wire_bytes=wire)


def roofline_terms(
    flops_total: float,
    bytes_total: float,
    coll: CollectiveStats,
    num_devices: int,
    model_flops: Optional[float] = None,
) -> dict:
    """Three roofline terms in seconds + diagnostics.

    ``flops_total``/``bytes_total`` are whole-program HLO numbers from
    cost_analysis (already per-partition after SPMD on CPU dry-runs we
    multiply/divide explicitly at the call site — see dryrun.py)."""
    t_compute = flops_total / (num_devices * PEAK_FLOPS)
    t_memory = bytes_total / (num_devices * HBM_BW)
    # wire bytes are per-device ring traffic; each chip drives its links
    t_collective = coll.wire_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_collective), key=lambda kv: kv[1])
    out = dict(
        t_compute=t_compute, t_memory=t_memory, t_collective=t_collective,
        dominant=dominant[0], bound_seconds=dominant[1],
        collective_counts=coll.counts,
        collective_bytes=coll.total_bytes,
        wire_bytes=coll.wire_bytes,
    )
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flop_frac"] = model_flops / max(flops_total, 1.0)
        # roofline fraction: useful work / (time lower-bounded by dominant term)
        t_ideal = model_flops / (num_devices * PEAK_FLOPS)
        out["roofline_fraction"] = t_ideal / max(dominant[1], 1e-30)
    return out

"""Training launcher: ``--arch`` selects any assigned architecture and runs
real (CPU-scale, reduced-config by default) training with the production
code path — sharded step, checkpointing, restart, watchdog.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 100 [--full-config] [--ckpt-dir DIR] [--grad-accum 2]

On a real TPU pod this same entry point runs the full configs; here the
smoke configs keep it laptop-sized (full configs are exercised by the
dry-run, per the assignment).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.dist.elastic import make_mesh_for
from repro.train.optim import adamw, cosine_schedule
from repro.train.trainer import Trainer


def _lm_setup(cfg, args):
    from repro.models.transformer import init_params, loss_fn
    from repro.data.tokens import synthetic_lm_batches
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    batches = synthetic_lm_batches(
        args.batch, args.seq, cfg.vocab, seed=args.seed,
        grad_accum=args.grad_accum if args.grad_accum > 1 else 0)
    return params, (lambda p, b: loss_fn(p, b, cfg)), batches


def _gnn_setup(cfg, args):
    from repro.data.graphs import cora_like
    from repro.models.gnn import gnn_loss_fn, init_gnn
    cfg = dataclasses.replace(cfg, d_in=32)
    g, batch = cora_like(n=2048, m=8192, d_feat=32,
                         n_classes=cfg.n_classes, seed=args.seed)
    params = init_gnn(jax.random.PRNGKey(args.seed), cfg)

    def batches():
        while True:
            yield batch

    return params, (lambda p, b: gnn_loss_fn(p, b, cfg)), batches()


def _recsys_setup(cfg, args):
    from repro.data.recsys import synthetic_recsys_batches
    from repro.models.bert4rec import bert4rec_loss_fn, init_bert4rec
    params = init_bert4rec(cfg, jax.random.PRNGKey(args.seed))
    batches = synthetic_recsys_batches(args.batch, cfg.max_len, cfg.vocab,
                                       cfg.mask_id, seed=args.seed)
    return params, (lambda p, b: bert4rec_loss_fn(p, b, cfg)), batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full literature config (TPU-scale!)")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.make_model_cfg() if args.full_config else spec.make_smoke_cfg()
    print(f"arch={args.arch} family={spec.family} cfg={cfg}")
    setup = {"lm": _lm_setup, "gnn": _gnn_setup, "recsys": _recsys_setup}
    params, loss_fn, batches = setup[spec.family](cfg, args)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n/1e6:.2f}M")

    mesh = make_mesh_for() if jax.device_count() > 1 else None
    trainer = Trainer(
        loss_fn=loss_fn,
        optimizer=adamw(cosine_schedule(args.lr, 20, args.steps)),
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 10),
        grad_accum=args.grad_accum, mesh=mesh)
    p, s = trainer.init_state(params)
    start = 0
    if args.ckpt_dir:
        p, s, start = trainer.maybe_restore(p, s)
        if start:
            print(f"resumed from step {start}")
    p, s, hist = trainer.run(p, s, batches, start_step=start,
                             num_steps=args.steps, log_every=10)
    print(f"done: loss {hist[0]['loss']:.4f} → {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

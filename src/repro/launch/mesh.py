"""Production mesh factory (assignment-mandated shape).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "POD_CHIPS"]

POD_CHIPS = 256  # 16×16 v5e pod


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)

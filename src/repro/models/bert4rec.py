"""BERT4Rec [arXiv:1904.06690]: bidirectional transformer over item sequences.

Cloze (masked-item) training; serving scores the hidden state at the mask
position against the item embedding table (tied weights).  The retrieval
cell scores one user against 10⁶ candidates as a single batched GEMM (no
loops), per the assignment.

The item-embedding gradient accumulation is the push-mode TOCAB pattern
(many token-gradients scatter into few hot rows) — exercised explicitly by
``binned_embedding_grad`` and used as an optional transform in the trainer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from .layers import cross_entropy_loss, init_dense

Array = jnp.ndarray

__all__ = ["Bert4RecCfg", "init_bert4rec", "bert4rec_encode",
           "bert4rec_loss_fn", "bert4rec_score", "bert4rec_retrieve",
           "binned_embedding_grad"]


@dataclasses.dataclass(frozen=True)
class Bert4RecCfg:
    name: str
    vocab: int  # num items (+1 mask +1 pad handled inside)
    max_len: int
    d_model: int
    n_blocks: int
    n_heads: int
    d_ff_mult: int = 4
    dropout: float = 0.0  # kept 0 (deterministic); field for completeness
    # full softmax is paper-faithful for small vocab; at 10⁶ items training
    # uses sampled softmax with shared negatives (industry standard)
    max_masked: int = 20
    num_negatives: int = 1024

    @property
    def sampled_softmax(self) -> bool:
        return self.vocab > 50_000

    @property
    def mask_id(self) -> int:
        return self.vocab

    @property
    def pad_id(self) -> int:
        return self.vocab + 1

    @property
    def table_size(self) -> int:
        return self.vocab + 2


def init_bert4rec(cfg: Bert4RecCfg, key) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    d = cfg.d_model
    blocks = []
    for i in range(cfg.n_blocks):
        b = jax.random.split(ks[2 + i], 6)
        blocks.append({
            "wq": init_dense(b[0], d, d), "wk": init_dense(b[1], d, d),
            "wv": init_dense(b[2], d, d), "wo": init_dense(b[3], d, d),
            "w1": init_dense(b[4], d, cfg.d_ff_mult * d),
            "w2": init_dense(b[5], cfg.d_ff_mult * d, d),
            "ln1": jnp.ones((d,)), "b_ln1": jnp.zeros((d,)),
            "ln2": jnp.ones((d,)), "b_ln2": jnp.zeros((d,)),
        })
    return {
        "item_emb": jax.random.normal(ks[0], (cfg.table_size, d)) * 0.02,
        "pos_emb": jax.random.normal(ks[1], (cfg.max_len, d)) * 0.02,
        "blocks": blocks,
        "ln_out": jnp.ones((d,)), "b_ln_out": jnp.zeros((d,)),
    }


def _ln(x, g, b, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def bert4rec_encode(params: dict, items: Array, cfg: Bert4RecCfg,
                    dtype=jnp.float32) -> Array:
    """items (B, L) int32 → hidden (B, L, d).  Bidirectional attention with
    padding mask.  ``dtype=bf16`` is the serving fast path (§Perf)."""
    B, L = items.shape
    items = shard(items, "batch", None)
    params = jax.tree.map(lambda a: a.astype(dtype)
                          if a.dtype == jnp.float32 else a, params)
    x = jnp.take(params["item_emb"], items, axis=0) + params["pos_emb"][None, :L]
    x = shard(x, "batch", None, None)
    pad = items == cfg.pad_id  # (B, L)
    bias = jnp.where(pad[:, None, None, :], -1e30, 0.0)  # (B,1,1,L)
    H = cfg.n_heads
    hd = cfg.d_model // H
    for p in params["blocks"]:
        h = _ln(x, p["ln1"], p["b_ln1"])
        q = (h @ p["wq"]).reshape(B, L, H, hd).transpose(0, 2, 1, 3)
        k = (h @ p["wk"]).reshape(B, L, H, hd).transpose(0, 2, 1, 3)
        v = (h @ p["wv"]).reshape(B, L, H, hd).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * hd ** -0.5 + bias
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", a, v).transpose(0, 2, 1, 3).reshape(B, L, -1)
        x = x + o @ p["wo"]
        h = _ln(x, p["ln2"], p["b_ln2"])
        x = x + jax.nn.gelu(h @ p["w1"], approximate=True) @ p["w2"]
    return _ln(x, params["ln_out"], params["b_ln_out"])


def bert4rec_loss_fn(params: dict, batch: dict, cfg: Bert4RecCfg):
    """Small vocab (paper-faithful full softmax):
        batch = {items (B,L) w/ MASK, labels (B,L), label_mask (B,L)}
    Huge vocab (sampled softmax, shared negatives):
        batch additionally has mask_pos (B,M) int32, pos_labels (B,M),
        pos_weight (B,M), negatives (K,) int32."""
    h = bert4rec_encode(params, batch["items"], cfg)
    if not cfg.sampled_softmax:
        logits = jnp.einsum("bld,vd->blv", h, params["item_emb"][: cfg.vocab])
        logits = shard(logits, "batch", None, "vocab")
        loss = cross_entropy_loss(logits, batch["labels"], batch["label_mask"])
        return loss, {"ce": loss}
    # gather hidden states at masked positions: (B, M, d)
    hm = jnp.take_along_axis(h, batch["mask_pos"][..., None], axis=1)
    emb = params["item_emb"]
    pos_e = jnp.take(emb, batch["pos_labels"], axis=0)  # (B, M, d)
    neg_e = jnp.take(emb, batch["negatives"], axis=0)  # (K, d)
    s_pos = (hm * pos_e).sum(-1)  # (B, M)
    s_neg = jnp.einsum("bmd,kd->bmk", hm, neg_e)  # (B, M, K)
    # exclude accidental hits (negative == label)
    hit = batch["negatives"][None, None, :] == batch["pos_labels"][..., None]
    s_neg = jnp.where(hit, -1e30, s_neg)
    logits = jnp.concatenate([s_pos[..., None], s_neg], axis=-1)  # (B,M,1+K)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    nll = logz - s_pos.astype(jnp.float32)
    w = batch["pos_weight"]
    loss = (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
    return loss, {"ce": loss}


def bert4rec_score(params: dict, items: Array, cfg: Bert4RecCfg,
                   top_k: int = 100):
    """Online/offline scoring: hidden at the final position vs all items →
    top-k (the serve_p99 / serve_bulk cells).  The (B, V) score matrix is
    sharded over batch×vocab; top-k reduces across the vocab shards."""
    h = bert4rec_encode(params, items, cfg, dtype=jnp.bfloat16)
    user = h[:, -1, :]  # next-item convention: last position holds MASK
    scores = jnp.einsum("bd,vd->bv", user,
                        params["item_emb"][: cfg.vocab].astype(jnp.bfloat16))
    scores = shard(scores, "batch", "vocab").astype(jnp.float32)
    # §Perf H2: two-stage sharded top-k — plain top_k over a vocab-sharded
    # matrix all-gathers (B, V) per device (~TiB at serve_bulk scale)
    from repro.dist.sharding import current_mesh
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.shape:
        from repro.dist.collectives import distributed_topk
        return distributed_topk(scores, top_k, mesh)
    return jax.lax.top_k(scores, top_k)


def bert4rec_retrieve(params: dict, items: Array, candidates: Array,
                      cfg: Bert4RecCfg, top_k: int = 100):
    """retrieval_cand cell: batch=1 user vs n_candidates item ids.
    One gather + one GEMV; returns (top scores, top ids)."""
    h = bert4rec_encode(params, items, cfg)
    user = h[:, -1, :]  # (1, d)
    cand_emb = jnp.take(params["item_emb"], candidates, axis=0)  # (C, d)
    cand_emb = shard(cand_emb, "candidates", None)
    scores = (cand_emb @ user[0]).astype(jnp.float32)  # (C,)
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, jnp.take(candidates, idx)


def binned_embedding_grad(token_ids: Array, grads: Array, table_size: int,
                          num_bins: int = 64) -> Array:
    """Push-mode TOCAB for the embedding gradient: sort token-gradient pairs
    by destination row *bin* (the runtime binning pass), then accumulate —
    on TPU each bin's scatter stays in a VMEM-sized window.  Numerically
    identical to a flat segment_sum (asserted in tests)."""
    flat_ids = token_ids.reshape(-1)
    flat_g = grads.reshape(-1, grads.shape[-1])
    bin_size = -(-table_size // num_bins)
    order = jnp.argsort(flat_ids // bin_size)  # binning pass
    sid = flat_ids[order]
    sg = flat_g[order]
    return jax.ops.segment_sum(sg, sid, num_segments=table_size)

"""Shared neural-net layers (pure JAX, explicit param pytrees, no flax).

Covers everything the assigned LM architectures need: RMSNorm, RoPE, GQA
attention with sliding-window / logit-softcap / local-global patterns,
SwiGLU / GeGLU MLPs, tied embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.kernels.flash_attention.ops import attention as attn_op

__all__ = [
    "RMSNormP", "rms_norm", "rope", "init_dense", "dense",
    "init_attention", "attention_block", "decode_attention_block",
    "init_mlp", "mlp_block", "cross_entropy_loss",
]

Array = jnp.ndarray


# --------------------------------------------------------------------- #
# basics
# --------------------------------------------------------------------- #
def rms_norm(x: Array, gamma: Array, eps: float = 1e-6, plus_one: bool = False) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    g = (1.0 + gamma) if plus_one else gamma  # gemma uses (1+w)
    return (y * g).astype(x.dtype)


def rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, scale: Optional[float] = None) -> Array:
    scale = scale if scale is not None else d_in ** -0.5
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def dense(x: Array, w: Array) -> Array:
    return jnp.einsum("...i,io->...o", x, w)


def cross_entropy_loss(logits: Array, labels: Array, mask: Optional[Array] = None,
                       softcap: float = 0.0) -> Array:
    """Mean next-token CE.  logits (..., V) fp32; labels int (...,)."""
    logits = logits.astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# --------------------------------------------------------------------- #
# attention (GQA + RoPE + sliding window + softcap)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: int = 0  # 0 = global
    softcap: float = 0.0
    causal: bool = True
    scale: Optional[float] = None  # None → head_dim**-0.5


def init_attention(key, cfg: AttnCfg) -> dict:
    ks = jax.random.split(key, 4)
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": jax.random.normal(ks[0], (d, H, hd), jnp.float32) * d ** -0.5,
        "wk": jax.random.normal(ks[1], (d, Hk, hd), jnp.float32) * d ** -0.5,
        "wv": jax.random.normal(ks[2], (d, Hk, hd), jnp.float32) * d ** -0.5,
        "wo": jax.random.normal(ks[3], (H, hd, d), jnp.float32) * (H * hd) ** -0.5,
    }


def _qkv(params, x, positions, cfg: AttnCfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(
    params: dict,
    x: Array,  # (B, S, d)
    positions: Array,  # (B, S)
    cfg: AttnCfg,
    backend: str = "xla",
) -> Array:
    q, k, v = _qkv(params, x, positions, cfg)
    q = shard(jnp.swapaxes(q, 1, 2), "batch", "heads", "seq", None)  # (B,H,S,hd)
    k = shard(jnp.swapaxes(k, 1, 2), "batch", "kv_heads", "seq", None)
    v = shard(jnp.swapaxes(v, 1, 2), "batch", "kv_heads", "seq", None)
    o = attn_op(
        q, k, v,
        scale=cfg.scale, causal=cfg.causal, window=cfg.window,
        softcap=cfg.softcap, backend=backend,
    )
    o = jnp.swapaxes(o, 1, 2)  # (B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


def decode_attention_block(
    params: dict,
    x: Array,  # (B, 1, d) — one new token
    pos: Array,  # scalar int32 — current position
    k_cache: Array,  # (B, Hkv, S_max, hd)
    v_cache: Array,
    cfg: AttnCfg,
) -> tuple[Array, Array, Array]:
    """One decode step against a KV cache (serve_step hot path).

    Sliding-window layers keep a ring buffer: the cache holds only
    ``min(window, S_max)`` positions and the write index wraps."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(params, x, positions, cfg)
    q = jnp.swapaxes(q, 1, 2)  # (B, H, 1, hd)
    k_new = jnp.swapaxes(k_new, 1, 2)  # (B, Hkv, 1, hd)
    v_new = jnp.swapaxes(v_new, 1, 2)
    S_max = k_cache.shape[2]
    write_idx = jnp.where(S_max > 0, pos % S_max, 0)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, 0, write_idx, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, 0, write_idx, 0))
    # positions of cache slots (ring-aware): slot i holds absolute position
    #   i                      if pos < S_max   (not yet wrapped)
    #   pos - ((write_idx - i) mod S_max)       after wrapping
    slots = jnp.arange(S_max, dtype=jnp.int32)
    abs_pos = pos - jnp.mod(write_idx - slots, S_max)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if cfg.window > 0:
        valid &= (pos - abs_pos) < cfg.window
    group = cfg.n_heads // cfg.n_kv_heads
    kc = jnp.repeat(k_cache, group, axis=1).astype(jnp.float32)
    vc = jnp.repeat(v_cache, group, axis=1).astype(jnp.float32)
    scale = cfg.scale if cfg.scale is not None else cfg.head_dim ** -0.5
    s = jnp.einsum("bhqk,bhsk->bhqs", q.astype(jnp.float32) * scale, kc)
    if cfg.softcap > 0.0:
        s = cfg.softcap * jnp.tanh(s / cfg.softcap)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bhsk->bhqk", p, vc).astype(x.dtype)
    o = jnp.swapaxes(o, 1, 2)  # (B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, k_cache, v_cache


# --------------------------------------------------------------------- #
# MLP (SwiGLU / GeGLU / plain GELU)
# --------------------------------------------------------------------- #
def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu") -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": init_dense(ks[0], d_model, d_ff),
        "w_down": init_dense(ks[1], d_ff, d_model),
    }
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = init_dense(ks[2], d_model, d_ff)
    return p


def mlp_block(params: dict, x: Array, kind: str = "swiglu") -> Array:
    up = dense(x, params["w_up"].astype(x.dtype))
    if kind == "swiglu":
        gate = jax.nn.silu(dense(x, params["w_gate"].astype(x.dtype)))
        h = gate * up
    elif kind == "geglu":
        gate = jax.nn.gelu(dense(x, params["w_gate"].astype(x.dtype)), approximate=True)
        h = gate * up
    else:  # gelu
        h = jax.nn.gelu(up, approximate=True)
    h = shard(h, "batch", "seq", "mlp")
    return dense(h, params["w_down"].astype(x.dtype))

"""Decoder-only LM covering the five assigned transformer architectures.

Features: GQA, RoPE, SwiGLU/GeGLU, RMSNorm (gemma ``1+γ`` form), sliding-
window attention (Mixtral), alternating local/global layers + attn & final
logit soft-capping (Gemma-2), MoE with TOCAB-binned dispatch (Granite,
Mixtral), tied embeddings, scan-over-layers with per-layer remat.

Layer parameters are stacked on a leading ``layers`` axis and the forward
pass is a ``lax.scan`` — keeps the HLO small enough to compile 56-layer
models for 512 devices, and matches how production frameworks lower.

Entry points:
  init_params / loss_fn (train), serve_prefill, serve_decode (KV cache).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from .layers import (
    AttnCfg,
    attention_block,
    cross_entropy_loss,
    decode_attention_block,
    init_attention,
    init_mlp,
    mlp_block,
    rms_norm,
)
from .moe import MoECfg, init_moe, moe_block

Array = jnp.ndarray

__all__ = ["TransformerCfg", "KVCache", "init_params", "forward",
           "loss_fn", "serve_prefill", "serve_decode", "init_cache"]


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    mlp_kind: str = "swiglu"
    rope_theta: float = 10000.0
    # attention pattern: "global" | "window" | "alternating" (local, global, …)
    layer_pattern: str = "global"
    window: int = 0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    attn_scale: Optional[float] = None
    norm_plus_one: bool = False  # gemma-style (1+γ) RMSNorm
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True
    # MoE (None → dense FFN)
    num_experts: int = 0
    top_k: int = 0
    moe_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    moe_dispatch: str = "sharded"  # global | sharded (§Perf H1b)
    remat: bool = True
    remat_policy: str = "full"  # full | dots (§Perf: save GEMM outputs,
    #                              recompute attention/elementwise)
    compute_dtype: str = "bfloat16"
    # scan-over-layers keeps HLO small (dry-run/compile); the roofline pass
    # unrolls (use_scan=False) because HLO cost analysis counts a while-loop
    # body once, not × trip-count
    use_scan: bool = True

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def pair_scan(self) -> bool:
        return self.layer_pattern == "alternating"

    def attn_cfg(self, local: bool) -> AttnCfg:
        if self.layer_pattern == "global":
            window = 0
        elif self.layer_pattern == "window":
            window = self.window
        else:  # alternating
            window = self.window if local else 0
        return AttnCfg(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            rope_theta=self.rope_theta, window=window,
            softcap=self.attn_softcap, causal=True, scale=self.attn_scale,
        )

    def moe_cfg(self) -> MoECfg:
        return MoECfg(
            d_model=self.d_model, d_ff=self.d_ff,
            num_experts=self.num_experts, top_k=self.top_k,
            capacity_factor=self.capacity_factor, kind=self.mlp_kind,
            dispatch=self.moe_dispatch,
        )

    def param_count(self) -> int:
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        ffn = gates * d * f * (self.num_experts if self.is_moe else 1)
        ffn += d * self.num_experts if self.is_moe else 0
        return L * (attn + ffn + 2 * d) + V * d + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = gates * d * f * self.top_k + d * self.num_experts
        return L * (attn + ffn + 2 * d) + self.vocab * d + d


# --------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------- #
def _init_layer(key, cfg: TransformerCfg) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": jnp.zeros((cfg.d_model,)) if cfg.norm_plus_one
        else jnp.ones((cfg.d_model,)),
        "ln_mlp": jnp.zeros((cfg.d_model,)) if cfg.norm_plus_one
        else jnp.ones((cfg.d_model,)),
        "attn": init_attention(ks[0], cfg.attn_cfg(local=True)),
    }
    if cfg.is_moe:
        p["moe"] = init_moe(ks[1], cfg.moe_cfg())
    else:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    return p


def init_params(cfg: TransformerCfg, key) -> dict:
    kl, ke, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    if cfg.pair_scan:
        # restack (L, ...) → (L/2, 2, ...) for the local/global pair scan
        assert cfg.n_layers % 2 == 0
        layers = jax.tree.map(
            lambda x: x.reshape((cfg.n_layers // 2, 2) + x.shape[1:]), layers
        )
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), jnp.float32)
        * cfg.d_model ** -0.5,
        "ln_final": jnp.zeros((cfg.d_model,)) if cfg.norm_plus_one
        else jnp.ones((cfg.d_model,)),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(kh, (cfg.vocab, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5
        )
    return params


def param_logical_axes(cfg: TransformerCfg) -> dict:
    """Logical sharding axes per param (mirrors the param tree)."""
    lead = ("layers", None) if cfg.pair_scan else ("layers",)
    layer = {
        "ln_attn": lead + (None,),
        "ln_mlp": lead + (None,),
        "attn": {
            "wq": lead + ("fsdp", "heads", None),
            "wk": lead + ("fsdp", "kv_heads", None),
            "wv": lead + ("fsdp", "kv_heads", None),
            "wo": lead + ("heads", None, "fsdp"),
        },
    }
    if cfg.is_moe:
        moe = {
            "router": lead + ("fsdp", None),
            "w_up": lead + ("experts", "fsdp", "mlp"),
            "w_down": lead + ("experts", "mlp", "fsdp"),
        }
        if cfg.mlp_kind in ("swiglu", "geglu"):
            moe["w_gate"] = lead + ("experts", "fsdp", "mlp")
        layer["moe"] = moe
    else:
        mlp = {
            "w_up": lead + ("fsdp", "mlp"),
            "w_down": lead + ("mlp", "fsdp"),
        }
        if cfg.mlp_kind in ("swiglu", "geglu"):
            mlp["w_gate"] = lead + ("fsdp", "mlp")
        layer["mlp"] = mlp
    tree = {
        "embed": ("vocab", "fsdp"),
        "ln_final": (None,),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = ("vocab", "fsdp")
    return tree


# --------------------------------------------------------------------- #
# forward (training / prefill)
# --------------------------------------------------------------------- #
def _layer_apply(p, x, positions, cfg: TransformerCfg, local: bool):
    acfg = cfg.attn_cfg(local)
    h = rms_norm(x, p["ln_attn"], plus_one=cfg.norm_plus_one)
    x = x + attention_block(p["attn"], h, positions, acfg)
    h = rms_norm(x, p["ln_mlp"], plus_one=cfg.norm_plus_one)
    if cfg.is_moe:
        y, aux = moe_block(p["moe"], h, cfg.moe_cfg())
    else:
        y, aux = mlp_block(p["mlp"], h, cfg.mlp_kind), jnp.float32(0.0)
    return x + y, aux


def _embed(params, tokens, cfg: TransformerCfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x.astype(cfg.compute_dtype)


def _unembed(params, x, cfg: TransformerCfg):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    if cfg.final_softcap > 0.0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def forward(params: dict, tokens: Array, cfg: TransformerCfg) -> tuple[Array, Array]:
    """tokens (B, S) → (logits (B, S, V) fp32, moe aux loss)."""
    B, S = tokens.shape
    tokens = shard(tokens, "batch", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = shard(_embed(params, tokens, cfg), "batch", None, "embed")

    def body(carry, p):
        x, aux = carry
        if cfg.pair_scan:
            p0 = jax.tree.map(lambda a: a[0], p)
            p1 = jax.tree.map(lambda a: a[1], p)
            x, a0 = _layer_apply(p0, x, positions, cfg, local=True)
            x, a1 = _layer_apply(p1, x, positions, cfg, local=False)
            aux = aux + a0 + a1
        else:
            x, a = _layer_apply(p, x, positions, cfg, local=True)
            aux = aux + a
        return (x, aux), None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    if cfg.use_scan:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    else:
        carry = (x, jnp.float32(0.0))
        n_steps = jax.tree.leaves(params["layers"])[0].shape[0]
        for i in range(n_steps):
            p_i = jax.tree.map(lambda a: a[i], params["layers"])
            carry, _ = body(carry, p_i)
        x, aux = carry
    x = rms_norm(x, params["ln_final"], plus_one=cfg.norm_plus_one)
    logits = _unembed(params, x, cfg)
    return shard(logits, "batch", None, "vocab"), aux


def loss_fn(params: dict, batch: dict, cfg: TransformerCfg) -> tuple[Array, dict]:
    """batch = {tokens (B,S), loss_mask optional} → (loss, metrics)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], cfg)
    labels = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = mask[:, 1:] if mask is not None else None
    ce = cross_entropy_loss(logits, labels, mask)
    loss = ce + cfg.moe_aux_coef * aux
    return loss, {"ce": ce, "moe_aux": aux}


# --------------------------------------------------------------------- #
# serving: prefill + decode with ring-buffer KV caches
# --------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Stacked caches.  For ``alternating`` the local half uses a ring of
    size=window while the global half holds the full horizon."""
    k: Array  # (n_scan, B, Hkv, S_local_or_full, hd)
    v: Array
    k2: Optional[Array] = None  # global half (pair scan only)
    v2: Optional[Array] = None


def cache_len(cfg: TransformerCfg, horizon: int) -> int:
    if cfg.layer_pattern == "window":
        return min(cfg.window, horizon)
    return horizon


def init_cache(cfg: TransformerCfg, batch: int, horizon: int,
               dtype=jnp.bfloat16) -> KVCache:
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.pair_scan:
        n = cfg.n_layers // 2
        local_len = min(cfg.window, horizon) if cfg.window else horizon
        return KVCache(
            k=jnp.zeros((n, batch, hk, local_len, hd), dtype),
            v=jnp.zeros((n, batch, hk, local_len, hd), dtype),
            k2=jnp.zeros((n, batch, hk, horizon, hd), dtype),
            v2=jnp.zeros((n, batch, hk, horizon, hd), dtype),
        )
    L = cfg.n_layers
    s = cache_len(cfg, horizon)
    return KVCache(
        k=jnp.zeros((L, batch, hk, s, hd), dtype),
        v=jnp.zeros((L, batch, hk, s, hd), dtype),
    )


def serve_prefill(params: dict, tokens: Array, cfg: TransformerCfg):
    """Prefill: full forward returning last-position logits (B, V).

    (Cache materialization is a by-product on real serving paths; the
    prefill cell lowers the compute-dominant part — the full forward.)"""
    logits, _ = forward(params, tokens, cfg)
    return logits[:, -1, :]


def serve_decode(params: dict, token: Array, pos: Array, cache: KVCache,
                 cfg: TransformerCfg):
    """One decode step.  token (B, 1) int32; pos scalar int32.
    Returns (logits (B, V), new cache)."""
    x = _embed(params, token, cfg)
    x = shard(x, "batch", None, "embed")

    def body(carry, xs):
        x = carry
        if cfg.pair_scan:
            p, kc, vc, kc2, vc2 = xs
            p0 = jax.tree.map(lambda a: a[0], p)
            p1 = jax.tree.map(lambda a: a[1], p)
            x, kc, vc = _decode_layer(p0, x, pos, kc, vc, cfg, local=True)
            x, kc2, vc2 = _decode_layer(p1, x, pos, kc2, vc2, cfg, local=False)
            return x, (kc, vc, kc2, vc2)
        p, kc, vc = xs
        x, kc, vc = _decode_layer(p, x, pos, kc, vc, cfg, local=True)
        return x, (kc, vc)

    if cfg.pair_scan:
        xs = (params["layers"], cache.k, cache.v, cache.k2, cache.v2)
    else:
        xs = (params["layers"], cache.k, cache.v)
    if cfg.use_scan:
        x, caches = jax.lax.scan(body, x, xs)
    else:
        n_steps = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n_steps):
            xs_i = jax.tree.map(lambda a: a[i], xs)
            x, y = body(x, xs_i)
            ys.append(y)
        caches = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    x = rms_norm(x, params["ln_final"], plus_one=cfg.norm_plus_one)
    logits = _unembed(params, x[:, 0, :], cfg)
    if cfg.pair_scan:
        new_cache = KVCache(k=caches[0], v=caches[1], k2=caches[2], v2=caches[3])
    else:
        new_cache = KVCache(k=caches[0], v=caches[1])
    return logits, new_cache


def _decode_layer(p, x, pos, kc, vc, cfg: TransformerCfg, local: bool):
    acfg = cfg.attn_cfg(local)
    h = rms_norm(x, p["ln_attn"], plus_one=cfg.norm_plus_one)
    o, kc, vc = decode_attention_block(p["attn"], h, pos, kc, vc, acfg)
    x = x + o
    h = rms_norm(x, p["ln_mlp"], plus_one=cfg.norm_plus_one)
    if cfg.is_moe:
        # decode: token counts are tiny — per-shard binning would force the
        # expert weights to all-gather over the data axis (§Perf, measured
        # 14× collective regression); global dispatch keeps weights sharded
        mcfg = dataclasses.replace(cfg.moe_cfg(), dispatch="global")
        y, _ = moe_block(p["moe"], h, mcfg)
    else:
        y = mlp_block(p["mlp"], h, cfg.mlp_kind)
    return x + y, kc, vc

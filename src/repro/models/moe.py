"""Mixture-of-Experts layer with TOCAB-style sorted (binned) dispatch.

The token→expert dispatch is a push-mode scatter: many tokens accumulate
into few expert bins.  We implement it exactly like the paper's push TOCAB
(§3.1): *bin* tokens by destination expert (sort), give every expert a dense
capacity slab ("subgraph" with compacted local slots), run dense per-expert
GEMMs (grouped einsum → MXU), then un-permute and combine — the reduction
phase.  No (tokens × experts × capacity) one-hot tensor is ever materialized,
which is what makes the 8×22B cells lowerable.

Two dispatch modes (§Perf H1 hillclimb):

* ``global``  — one sort over all tokens.  Paper-faithful single-bin pass,
  but on a sharded mesh the global argsort/scatter forces all-gathers of
  the full token stream per layer (measured: the dominant collective cost
  on the MoE train cells).
* ``sharded`` — hierarchical binning: every data shard bins **its own**
  tokens into per-shard capacity slabs (vmapped ⇒ the sort/scatter stay
  shard-local, zero collectives), expert GEMMs batch over the shard axis,
  combine is shard-local too.  This is the paper's own structure one level
  up: subgraph-local processing + a merge that never leaves the shard.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.sharding import current_mesh, shard
from .layers import init_dense

__all__ = ["MoECfg", "init_moe", "moe_block"]

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int  # per-expert hidden
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    kind: str = "swiglu"  # expert MLP kind
    router_softcap: float = 0.0
    dispatch: str = "sharded"  # global | sharded  (§Perf H1)


def init_moe(key, cfg: MoECfg) -> dict:
    ks = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": init_dense(ks[0], d, E),
        "w_up": jax.random.normal(ks[1], (E, d, f), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(ks[2], (E, f, d), jnp.float32) * f ** -0.5,
    }
    if cfg.kind in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(ks[3], (E, d, f), jnp.float32) * d ** -0.5
    return p


def _capacity(n_tokens: int, cfg: MoECfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def _num_token_shards(n: int) -> int:
    """Data-axis width used for hierarchical binning (1 off-mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    s = 1
    for ax in ("pod", "data"):
        s *= mesh.shape.get(ax, 1)
    return s if (s > 1 and n % s == 0) else 1


def _bin_and_dispatch(xt, gate_vals, expert_ids, E: int, C: int):
    """TOCAB binning of one token shard: sort by expert, dense capacity
    slabs with compacted slots.  Returns (dispatched(E,C,d), slab_idx,
    sorted_token, sorted_gate, keep)."""
    n, d = xt.shape
    k = expert_ids.shape[1]
    flat_expert = expert_ids.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)  # the binning pass
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    pos = jnp.arange(n * k, dtype=jnp.int32)
    bin_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    slot = pos - bin_start[se]
    keep = slot < C  # capacity drop (overflow falls back to the residual)
    slab_idx = jnp.where(keep, se * C + slot, E * C)  # pad bucket
    dispatched = jnp.zeros((E * C + 1, d), xt.dtype).at[slab_idx].set(
        jnp.take(xt, st, axis=0)
    )[: E * C].reshape(E, C, d)
    return dispatched, slab_idx, st, sg, keep


def _combine(expert_out, slab_idx, st, sg, keep, n: int):
    """Reduction phase: un-permute + gate-weighted combine (one shard)."""
    E_C, d = expert_out.shape[0] * expert_out.shape[1], expert_out.shape[2]
    flat_out = expert_out.reshape(E_C, d)
    gathered = jnp.take(flat_out, jnp.minimum(slab_idx, E_C - 1), axis=0)
    gathered = jnp.where((keep & (slab_idx < E_C))[:, None], gathered, 0.0)
    return jax.ops.segment_sum(
        gathered * sg[:, None].astype(gathered.dtype), st, num_segments=n)


def moe_block(params: dict, x: Array, cfg: MoECfg) -> tuple[Array, Array]:
    """x: (B, S, d) → (out, aux_loss)."""
    B, S, d = x.shape
    n = B * S
    E, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(n, d)

    # --- routing (row-local, no collectives) ---
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), params["router"])
    if cfg.router_softcap > 0.0:
        logits = cfg.router_softcap * jnp.tanh(logits / cfg.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (n, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E · Σ_e fraction_tokens(e) · mean_prob(e)
    frac = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    shards = _num_token_shards(n) if cfg.dispatch == "sharded" else 1
    n_l = n // shards
    C = _capacity(n_l, cfg)

    if shards == 1:
        dispatched, slab, st, sg, keep = _bin_and_dispatch(
            xt, gate_vals, expert_ids, E, C)
        dispatched = dispatched[None]  # (1, E, C, d)
    else:
        xs = xt.reshape(shards, n_l, d)
        gs = gate_vals.reshape(shards, n_l, k)
        es = expert_ids.reshape(shards, n_l, k)
        xs = shard(xs, "capacity", None, None)  # shard-local from here on
        dispatched, slab, st, sg, keep = jax.vmap(
            lambda a, b, c: _bin_and_dispatch(a, b, c, E, C))(xs, gs, es)
    dispatched = shard(dispatched, "capacity", "experts", None, None)

    # --- dense per-expert GEMMs (the "subgraph processing" phase) ---
    h_up = jnp.einsum("secd,edf->secf", dispatched,
                      params["w_up"].astype(xt.dtype))
    if cfg.kind in ("swiglu", "geglu"):
        g = jnp.einsum("secd,edf->secf", dispatched,
                       params["w_gate"].astype(xt.dtype))
        act = jax.nn.silu(g) if cfg.kind == "swiglu" else jax.nn.gelu(
            g, approximate=True)
        h = act * h_up
    else:
        h = jax.nn.gelu(h_up, approximate=True)
    h = shard(h, "capacity", "experts", None, "mlp")
    expert_out = jnp.einsum("secf,efd->secd", h,
                            params["w_down"].astype(xt.dtype))
    expert_out = shard(expert_out, "capacity", "experts", None, None)

    # --- reduction phase: un-permute + gate-weighted combine ---
    if shards == 1:
        combined = _combine(expert_out[0], slab, st, sg, keep, n)
    else:
        combined = jax.vmap(_combine, in_axes=(0, 0, 0, 0, 0, None))(
            expert_out, slab, st, sg, keep, n_l)
        combined = combined.reshape(n, d)
    out = combined.reshape(B, S, d).astype(x.dtype)
    return shard(out, "batch", "seq", None), aux

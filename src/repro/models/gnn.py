"""GNN zoo on top of the TOCAB message-passing engine.

The four assigned architectures — GAT (SDDMM + edge-softmax + SpMM), GIN
(sum aggregation + MLP), GraphSAGE (sampled mean aggregation), DimeNet
(radial/angular basis + triplet gather) — all route their edge→node
reductions through either the flat ``segment_sum`` baseline or the TOCAB
blocked engine (``agg='tocab'``), making the paper's technique a first-class
aggregation backend for GNN training.

JAX has no sparse message passing beyond BCOO; per the assignment the
SpMM/SDDMM primitive is built from ``jnp.take`` + ``jax.ops.segment_*`` here.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import BlockedGraph
from repro.core import tocab
from repro.dist.sharding import shard
from .layers import init_dense

Array = jnp.ndarray

__all__ = [
    "GraphBatch", "GNNConfig", "build_triplets",
    "init_gat", "gat_forward", "init_gin", "gin_forward",
    "init_sage", "sage_forward", "init_dimenet", "dimenet_forward",
    "gnn_loss_fn", "init_gnn", "gnn_forward",
]


# --------------------------------------------------------------------- #
# data containers
# --------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Static-shape (possibly padded) graph batch.

    For batched small graphs (``molecule``), ``graph_ids`` maps nodes to
    graphs.  For DimeNet, ``positions`` and the triplet edge-pair indices
    are present.  Padded edges point at node index n (dropped)."""

    node_feat: Array  # (N, F) float — or int atom types (N,) for dimenet
    edge_src: Array  # (E,) int32
    edge_dst: Array  # (E,) int32
    edge_mask: Array  # (E,) bool
    labels: Array  # (N,) int32 node labels | (G,) graph labels/targets
    node_mask: Optional[Array] = None  # (N,) bool
    positions: Optional[Array] = None  # (N, 3)
    graph_ids: Optional[Array] = None  # (N,) int32 for graph-level readout
    t_kj: Optional[Array] = None  # (T,) int32 — triplet edge k→j
    t_ji: Optional[Array] = None  # (T,) int32 — triplet edge j→i
    t_mask: Optional[Array] = None  # (T,) bool

    @property
    def n(self) -> int:
        return self.node_feat.shape[0]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch: str  # gat | gin | sage | dimenet
    n_layers: int
    d_in: int
    d_hidden: int
    n_classes: int
    n_heads: int = 1  # gat
    agg: str = "segment"  # segment | tocab
    graph_level: bool = False  # graph-level readout (molecule)
    # dimenet extras
    n_blocks: int = 6
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    # §Perf H3: bf16 messages/bases halve the memory term on the huge
    # triplet tensors; geometry + final reductions stay fp32
    compute_dtype: str = "float32"
    # §Perf H4: triplets arrive binned by destination-edge stripe (the
    # host partitioner sorts them — TOCAB's scatter-side alignment applied
    # to the mesh), so the triplet→edge reduce is shard-local: no
    # all-reduce.  Contract: all t_ji of data-shard s lie in its stripe.
    binned_triplets: bool = False
    # same contract for edges (sorted by destination-node stripe — exactly
    # the order repro.core.partition emits): edge→node reduces go local
    binned_edges: bool = False
    # sage
    sample_sizes: tuple = (25, 10)


def _agg(vals_e: Array, dst: Array, n: int, bg: Optional[BlockedGraph],
         reduce: str = "sum", binned: bool = False) -> Array:
    """Edge values → node aggregate, via TOCAB or flat segment reduce.
    ``binned`` engages the shard-local reduce (sum only) under the
    sorted-by-destination-stripe layout contract."""
    if bg is not None:
        return tocab.tocab_edge_reduce(bg, vals_e, reduce=reduce)
    if binned and reduce == "sum" and vals_e.ndim == 2:
        return _binned_segment_sum(vals_e, dst, n)
    return tocab.segment_reduce(vals_e, dst, n, reduce)


def _binned_segment_sum(vals: Array, seg: Array, n_out: int) -> Array:
    """Shard-local segment sum under the binned-by-stripe contract
    (§Perf H4): values and their destination stripe live on the same data
    shard, so the reduce needs zero collectives.  Falls back to the flat
    reduce off-mesh or when shapes don't divide."""
    from repro.dist.sharding import current_mesh
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = current_mesh()
    shards = mesh.shape.get("data", 1) if mesh is not None else 1
    if shards <= 1 or vals.shape[0] % shards or n_out % shards:
        return tocab.segment_reduce(vals, seg, n_out, "sum")
    n_loc = n_out // shards

    def local(v, s):
        lo = jax.lax.axis_index("data") * n_loc
        return jax.ops.segment_sum(v, s - lo, num_segments=n_loc)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P("data", None), P("data")),
        out_specs=P("data", None), check_rep=False,
    )(vals, seg)


def _masked_edges(batch: GraphBatch, vals_e: Array, fill=0.0) -> Array:
    m = batch.edge_mask
    while m.ndim < vals_e.ndim:
        m = m[..., None]
    return jnp.where(m, vals_e, fill)


def _graph_readout(x: Array, batch: GraphBatch) -> Array:
    """Sum-pool node states per graph (batched-small-graphs regime)."""
    num_graphs = int(batch.labels.shape[0])
    if batch.node_mask is not None:
        x = x * batch.node_mask.astype(x.dtype)[:, None]
    return tocab.segment_reduce(x, batch.graph_ids, num_graphs, "sum")


# --------------------------------------------------------------------- #
# GAT  [arXiv:1710.10903]
# --------------------------------------------------------------------- #
def init_gat(key, cfg: GNNConfig) -> dict:
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append({
            "w": init_dense(k1, d_in, heads * d_out),
            "a_src": jax.random.normal(k2, (heads, d_out)) * 0.1,
            "a_dst": jax.random.normal(k3, (heads, d_out)) * 0.1,
        })
        d_in = heads * d_out
    return {"layers": layers}


def _edge_softmax(scores_e: Array, dst: Array, n: int, edge_mask: Array,
                  bg: Optional[BlockedGraph]) -> Array:
    """Numerically-stable softmax over incoming edges per destination.
    scores_e: (E, H).  SDDMM → segment-max → exp → segment-sum."""
    neg = jnp.full_like(scores_e, -1e30)
    s = jnp.where(edge_mask[:, None], scores_e, neg)
    smax = _agg(s, dst, n, bg, reduce="max")  # (N, H)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = shard(jnp.exp(s - smax[dst]) * edge_mask[:, None], "edges", None)
    denom = _agg(ex, dst, n, bg, reduce="sum")
    return ex / jnp.maximum(denom[dst], 1e-16)


def gat_forward(params: dict, batch: GraphBatch, cfg: GNNConfig,
                bg: Optional[BlockedGraph] = None) -> Array:
    x = batch.node_feat
    n = batch.n
    src, dst = batch.edge_src, batch.edge_dst
    for i, p in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        heads = 1 if last else cfg.n_heads
        d_out = p["w"].shape[1] // heads
        h = (x @ p["w"]).reshape(n, heads, d_out)
        h = shard(h, "nodes", None, None)
        # SDDMM: per-edge attention logits
        s_src = jnp.einsum("nhd,hd->nh", h, p["a_src"])
        s_dst = jnp.einsum("nhd,hd->nh", h, p["a_dst"])
        scores = jax.nn.leaky_relu(s_src[src] + s_dst[dst], 0.2)  # (E, H)
        scores = shard(scores, "edges", None)
        alpha = _edge_softmax(scores, dst, n, batch.edge_mask, bg)
        msgs = _masked_edges(batch, h[src] * alpha[..., None])  # (E, H, D)
        msgs = shard(msgs, "edges", None, None)
        out = _agg(msgs.reshape(msgs.shape[0], -1), dst, n, bg,
                   binned=cfg.binned_edges).reshape(n, heads, d_out)
        x = out.reshape(n, heads * d_out)
        if not last:
            x = jax.nn.elu(x)
    if cfg.graph_level:
        x = _graph_readout(x, batch)
    return x  # logits (N or G, n_classes)


# --------------------------------------------------------------------- #
# GIN  [arXiv:1810.00826]
# --------------------------------------------------------------------- #
def init_gin(key, cfg: GNNConfig) -> dict:
    layers = []
    d_in = cfg.d_in
    for _ in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append({
            "eps": jnp.zeros(()),
            "w1": init_dense(k1, d_in, cfg.d_hidden),
            "b1": jnp.zeros((cfg.d_hidden,)),
            "w2": init_dense(k2, cfg.d_hidden, cfg.d_hidden),
            "b2": jnp.zeros((cfg.d_hidden,)),
        })
        d_in = cfg.d_hidden
    kh, key = jax.random.split(key)
    return {"layers": layers, "head": init_dense(kh, cfg.d_hidden, cfg.n_classes)}


def gin_forward(params: dict, batch: GraphBatch, cfg: GNNConfig,
                bg: Optional[BlockedGraph] = None) -> Array:
    x = batch.node_feat
    n = batch.n
    for p in params["layers"]:
        if bg is not None:
            agg = tocab.tocab_pull(bg, x, reduce="sum")
        else:
            msgs = shard(_masked_edges(batch, x[batch.edge_src]),
                         "edges", None)
            agg = _agg(msgs, batch.edge_dst, n, None,
                       binned=cfg.binned_edges)
        h = (1.0 + p["eps"]) * x + agg
        h = jax.nn.relu(h @ p["w1"] + p["b1"])
        x = jax.nn.relu(h @ p["w2"] + p["b2"])
        x = shard(x, "nodes", None)
    if cfg.graph_level:
        num_graphs = int(batch.labels.shape[0])
        gmask = batch.node_mask.astype(x.dtype)[:, None] if batch.node_mask is not None else 1.0
        x = tocab.segment_reduce(x * gmask, batch.graph_ids, num_graphs, "sum")
    return x @ params["head"]


# --------------------------------------------------------------------- #
# GraphSAGE  [arXiv:1706.02216]
# --------------------------------------------------------------------- #
def init_sage(key, cfg: GNNConfig) -> dict:
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, key = jax.random.split(key, 3)
        layers.append({
            "w_self": init_dense(k1, d_in, d_out),
            "w_neigh": init_dense(k2, d_in, d_out),
        })
        d_in = d_out
    return {"layers": layers}


def sage_forward(params: dict, batch: GraphBatch, cfg: GNNConfig,
                 bg: Optional[BlockedGraph] = None) -> Array:
    x = batch.node_feat
    n = batch.n
    ones = batch.edge_mask.astype(x.dtype)
    deg = _agg(ones, batch.edge_dst, n, bg)  # in-degree
    for i, p in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        if bg is not None:
            s = tocab.tocab_pull(bg, x, reduce="sum")
        else:
            msgs = shard(_masked_edges(batch, x[batch.edge_src]),
                         "edges", None)
            s = _agg(msgs, batch.edge_dst, n, None, binned=cfg.binned_edges)
        mean = s / jnp.maximum(deg[:, None], 1.0)
        x = x @ p["w_self"] + mean @ p["w_neigh"]
        x = shard(x, "nodes", None)
        if not last:
            x = jax.nn.relu(x)
            x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    if cfg.graph_level:
        x = _graph_readout(x, batch)
    return x


# --------------------------------------------------------------------- #
# DimeNet  [arXiv:2003.03123] — directional message passing
# --------------------------------------------------------------------- #
# Simplifications recorded in DESIGN.md §Arch-applicability: radial basis =
# the paper's sin(nπd/c)/d Bessel form; angular basis = Fourier cos(lθ)
# instead of full spherical Bessel × spherical harmonics (same tensor
# shapes and gather structure, which is what matters for the system).
def init_dimenet(key, cfg: GNNConfig) -> dict:
    ks = jax.random.split(key, 10)
    d = cfg.d_hidden
    nr, ns, nb = cfg.n_radial, cfg.n_spherical, cfg.n_bilinear
    return {
        "embed": init_dense(ks[0], cfg.d_in, d),
        "rbf_proj": init_dense(ks[1], nr, d),
        "blocks": [
            {
                "w_msg": init_dense(k1, d, d),
                "w_down": init_dense(k2, d, nb),
                "w_sbf": init_dense(k3, nr * ns, nb),
                "w_up": init_dense(k4, nb, d),
                "w_rbf": init_dense(k5, nr, d),
            }
            for (k1, k2, k3, k4, k5) in [
                jax.random.split(ks[2 + i], 5) for i in range(cfg.n_blocks)
            ]
        ],
        "out_rbf": init_dense(ks[8], cfg.n_radial, d),
        "head": init_dense(ks[9], d, cfg.n_classes),
    }


def _bessel_rbf(dist: Array, n_radial: int, cutoff: float) -> Array:
    """DimeNet radial basis: sin(nπ d/c) / d, n = 1..n_radial."""
    d = jnp.maximum(dist, 1e-6)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)[None, :]
    env = (2.0 / cutoff) ** 0.5
    return env * jnp.sin(n * jnp.pi * d / cutoff) / d


def _angular_basis(cos_angle: Array, n_spherical: int) -> Array:
    """Fourier angular basis cos(lθ), l = 0..n_spherical-1 (via Chebyshev)."""
    theta = jnp.arccos(jnp.clip(cos_angle, -1.0 + 1e-6, 1.0 - 1e-6))
    l = jnp.arange(n_spherical, dtype=jnp.float32)[None, :]
    return jnp.cos(l * theta[:, None])


def dimenet_forward(params: dict, batch: GraphBatch, cfg: GNNConfig,
                    bg: Optional[BlockedGraph] = None) -> Array:
    assert batch.positions is not None and batch.t_kj is not None
    n = batch.n
    src, dst = batch.edge_src, batch.edge_dst
    pos = batch.positions
    vec = shard(pos[src] - pos[dst], "edges", None)  # edge j→i (src=j)
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = _bessel_rbf(dist, cfg.n_radial, cfg.cutoff)  # (E, nr)
    rbf = shard(rbf * batch.edge_mask[:, None], "edges", None)

    # triplet geometry: angle between edge (k→j) and (j→i)
    v1 = vec[batch.t_ji]
    v2 = -vec[batch.t_kj]
    cos_a = (v1 * v2).sum(-1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-12
    )
    ang = _angular_basis(cos_a, cfg.n_spherical)  # (T, ns)
    sbf = (rbf[batch.t_kj][:, :, None] * ang[:, None, :]).reshape(
        ang.shape[0], cfg.n_radial * cfg.n_spherical
    )
    sbf = shard(sbf * batch.t_mask[:, None], "edges", None)

    # edge message embedding
    dt = jnp.dtype(cfg.compute_dtype)
    rbf = rbf.astype(dt)
    sbf = sbf.astype(dt)
    x_node = (batch.node_feat @ params["embed"]).astype(dt)
    wt = lambda w: w.astype(dt)
    m = jax.nn.silu(x_node[src] + x_node[dst] + rbf @ wt(params["rbf_proj"]))
    m = shard(m, "edges", None)

    E = src.shape[0]
    tmask = batch.t_mask.astype(dt)[:, None]
    emask = batch.edge_mask.astype(dt)[:, None]
    for blk in params["blocks"]:
        # directional (triplet) interaction: m_ji ← Σ_k  up[(down m_kj) ⊙ (sbf W)]
        m_down = shard((m @ wt(blk["w_down"]))[batch.t_kj], "edges", None)
        t_msg = m_down * (sbf @ wt(blk["w_sbf"]))  # (T, nb)
        t_msg = shard(t_msg, "edges", None)
        if cfg.binned_triplets:
            t_agg = _binned_segment_sum(t_msg * tmask, batch.t_ji, E)
        else:
            t_agg = tocab.segment_reduce(t_msg * tmask, batch.t_ji, E, "sum")
        t_agg = shard(t_agg, "edges", None)
        m = jax.nn.silu(m @ wt(blk["w_msg"]) + t_agg @ wt(blk["w_up"])
                        + rbf @ wt(blk["w_rbf"]))
        m = shard(m * emask, "edges", None)
    # output: edge → node
    node_out = _agg(m * (rbf @ wt(params["out_rbf"])), dst, n, bg,
                    binned=cfg.binned_edges)
    node_out = node_out.astype(jnp.float32)
    if cfg.graph_level:
        num_graphs = int(batch.labels.shape[0])
        node_out = tocab.segment_reduce(node_out, batch.graph_ids, num_graphs, "sum")
    return node_out @ params["head"]


# --------------------------------------------------------------------- #
# unified entry + loss
# --------------------------------------------------------------------- #
_INIT = {"gat": init_gat, "gin": init_gin, "sage": init_sage, "dimenet": init_dimenet}
_FWD = {"gat": gat_forward, "gin": gin_forward, "sage": sage_forward,
        "dimenet": dimenet_forward}


def init_gnn(key, cfg: GNNConfig) -> dict:
    return _INIT[cfg.arch](key, cfg)


def gnn_forward(params, batch, cfg: GNNConfig, bg=None) -> Array:
    return _FWD[cfg.arch](params, batch, cfg, bg)


def gnn_loss_fn(params, batch: GraphBatch, cfg: GNNConfig, bg=None):
    out = gnn_forward(params, batch, cfg, bg)
    if cfg.arch == "dimenet" and cfg.n_classes == 1:
        # regression (molecular property)
        target = batch.labels.astype(jnp.float32)
        loss = jnp.mean(jnp.square(out[..., 0] - target))
        return loss, {"mse": loss}
    logits = out.astype(jnp.float32)
    labels = batch.labels
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if not cfg.graph_level and batch.node_mask is not None:
        w = batch.node_mask.astype(jnp.float32)
        loss = (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
    else:
        loss = nll.mean()
    acc = jnp.mean((logits.argmax(-1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}


def build_triplets(src: np.ndarray, dst: np.ndarray, n: int,
                   cap_per_edge: int = 0, seed: int = 0):
    """Host-side triplet index construction for DimeNet.

    For every edge (j→i), pair it with incoming edges (k→j), k≠i.
    ``cap_per_edge>0`` truncates to that many k-neighbours per edge (the
    nearest-neighbour cap used for the huge assigned shapes).
    Returns (t_kj, t_ji, t_mask) padded to a static size."""
    E = len(src)
    in_edges = {}  # node → list of edge ids entering it
    for e, d in enumerate(dst):
        in_edges.setdefault(int(d), []).append(e)
    rng = np.random.default_rng(seed)
    t_kj, t_ji = [], []
    for e in range(E):
        j, i = int(src[e]), int(dst[e])
        cands = [ke for ke in in_edges.get(j, []) if int(src[ke]) != i]
        if cap_per_edge and len(cands) > cap_per_edge:
            cands = list(rng.choice(cands, cap_per_edge, replace=False))
        for ke in cands:
            t_kj.append(ke)
            t_ji.append(e)
    T = max(len(t_kj), 1)
    pad = -(-T // 128) * 128
    kj = np.zeros(pad, np.int32)
    ji = np.zeros(pad, np.int32)
    mask = np.zeros(pad, bool)
    kj[:len(t_kj)] = t_kj
    ji[:len(t_ji)] = t_ji
    mask[:len(t_kj)] = True
    return kj, ji, mask

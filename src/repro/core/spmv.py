"""SpMV (paper Fig. 7): y = A·x over the graph's weighted adjacency.

Most vertex programs are generalized SpMV (paper cites GraphMat) — this module
is both a benchmark and the oracle for the Pallas ``tocab_spmm`` kernel.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .graph import DeviceGraph
from .partition import BlockedGraph
from . import tocab

__all__ = ["spmv", "SPMV_VARIANTS"]

SPMV_VARIANTS = ("base", "push", "cb", "gc-pull", "gc-push")


@partial(jax.jit, static_argnames=("variant", "schedule"))
def spmv(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    x: jnp.ndarray,
    variant: str = "gc-pull",
    schedule: str = "uniform",
):
    """y[dst] = Σ_{(src,dst)} A[src,dst]·x[src].

    ``x`` may be a vector (n,) — SpMV — or a matrix (n, d) — SpMM, which is
    the GNN aggregation primitive.  ``schedule='balanced'`` runs the blocked
    variants with sparsity-aware per-bin strategies."""
    if variant == "base":
        return tocab.baseline_pull(dg, x, reduce="sum")
    if variant == "push":
        return tocab.baseline_push(dg, x, reduce="sum")
    if variant == "cb":
        return tocab.cb_pull(bg, x, reduce="sum")
    if variant == "gc-pull":
        return tocab.tocab_pull(bg, x, reduce="sum", schedule=schedule)
    if variant == "gc-push":
        return tocab.tocab_push(bg, x, reduce="sum", schedule=schedule)
    raise ValueError(f"unknown SpMV variant {variant!r}")

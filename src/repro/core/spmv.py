"""SpMV (paper Fig. 7): y = A·x over the graph's weighted adjacency.

Most vertex programs are generalized SpMV (paper cites GraphMat) — this module
is both a benchmark and the oracle for the Pallas ``tocab_spmm`` kernel.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .graph import DeviceGraph
from .partition import BlockedGraph
from . import tocab

__all__ = ["spmv", "SPMV_VARIANTS"]

SPMV_VARIANTS = ("base", "push", "cb", "gc-pull", "gc-push")


def spmv(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    x: jnp.ndarray,
    variant: str = "gc-pull",
    schedule: str = "uniform",
    dense_impl: Optional[str] = None,
    impl: str = "slab",
    scale=None,
    allow_fallback=None,
):
    """y[dst] = Σ_{(src,dst)} A[src,dst]·x[src].

    ``x`` may be a vector (n,) — SpMV — or a matrix (n, d) — SpMM, which is
    the GNN aggregation primitive.  ``schedule='balanced'`` runs the blocked
    variants with sparsity-aware per-bin strategies; ``schedule='auto'`` /
    ``impl='auto'`` consult the tuning DB (resolved here, outside jit).
    ``dense_impl`` forces the balanced dense-bin backend (``'pallas'`` /
    ``'onehot'``); ``impl='fused'`` routes the gc variants through the
    persistent no-partial-slab pipeline.  ``scale`` fuses ``y*scale`` into
    the engine epilogue (gc variants).  ``impl='auto'`` (or
    ``allow_fallback=True``) arms the fused→slab→reference degradation
    ladder on the gc variants."""
    from repro.resilience import degrade

    obj = bg if bg is not None else dg
    rs = tocab.resolve_schedule(obj, schedule, workload="spmv")
    ri = tocab.resolve_impl(obj, impl, workload="spmv")
    rs, ri = tocab._reconcile_fused(rs, ri, schedule, impl)
    allow = degrade.fallback_allowed(impl, allow_fallback)
    if allow and bg is not None and variant in ("gc-pull", "gc-push"):
        site = "tocab_pull" if variant == "gc-pull" else "tocab_push"
        ri = degrade.apply_verdict(bg.fingerprint, site, ri)
    return _spmv_jit(dg, bg, x, variant, rs, dense_impl, ri, scale, allow)


@partial(jax.jit, static_argnames=("variant", "schedule", "dense_impl",
                                   "impl", "allow_fallback"))
def _spmv_jit(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    x: jnp.ndarray,
    variant: str,
    schedule: str,
    dense_impl: Optional[str],
    impl: str = "slab",
    scale=None,
    allow_fallback: bool = False,
):
    epilogue = None if scale is None else (scale, 0.0)
    if variant == "base":
        y = tocab.baseline_pull(dg, x, reduce="sum")
    elif variant == "push":
        y = tocab.baseline_push(dg, x, reduce="sum")
    elif variant == "cb":
        y = tocab.cb_pull(bg, x, reduce="sum")
    elif variant == "gc-pull":
        return tocab.tocab_pull(bg, x, reduce="sum", schedule=schedule,
                                dense_impl=dense_impl, impl=impl,
                                epilogue=epilogue,
                                allow_fallback=allow_fallback)
    elif variant == "gc-push":
        return tocab.tocab_push(bg, x, reduce="sum", schedule=schedule,
                                impl=impl, epilogue=epilogue,
                                allow_fallback=allow_fallback)
    else:
        raise ValueError(f"unknown SpMV variant {variant!r}")
    return y if scale is None else y * scale

"""SpMV (paper Fig. 7): y = A·x over the graph's weighted adjacency.

Most vertex programs are generalized SpMV (paper cites GraphMat) — this module
is both a benchmark and the oracle for the Pallas ``tocab_spmm`` kernel.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .graph import DeviceGraph
from .partition import BlockedGraph
from . import tocab

__all__ = ["spmv", "SPMV_VARIANTS"]

SPMV_VARIANTS = ("base", "push", "cb", "gc-pull", "gc-push")


def spmv(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    x: jnp.ndarray,
    variant: str = "gc-pull",
    schedule: str = "uniform",
    dense_impl: Optional[str] = None,
):
    """y[dst] = Σ_{(src,dst)} A[src,dst]·x[src].

    ``x`` may be a vector (n,) — SpMV — or a matrix (n, d) — SpMM, which is
    the GNN aggregation primitive.  ``schedule='balanced'`` runs the blocked
    variants with sparsity-aware per-bin strategies; ``schedule='auto'``
    consults the tuning DB (resolved here, outside jit).  ``dense_impl``
    forces the balanced dense-bin backend (``'pallas'`` / ``'onehot'``)."""
    schedule = tocab.resolve_schedule(
        bg if bg is not None else dg, schedule, workload="spmv")
    return _spmv_jit(dg, bg, x, variant, schedule, dense_impl)


@partial(jax.jit, static_argnames=("variant", "schedule", "dense_impl"))
def _spmv_jit(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    x: jnp.ndarray,
    variant: str,
    schedule: str,
    dense_impl: Optional[str],
):
    if variant == "base":
        return tocab.baseline_pull(dg, x, reduce="sum")
    if variant == "push":
        return tocab.baseline_push(dg, x, reduce="sum")
    if variant == "cb":
        return tocab.cb_pull(bg, x, reduce="sum")
    if variant == "gc-pull":
        return tocab.tocab_pull(bg, x, reduce="sum", schedule=schedule,
                                dense_impl=dense_impl)
    if variant == "gc-push":
        return tocab.tocab_push(bg, x, reduce="sum", schedule=schedule)
    raise ValueError(f"unknown SpMV variant {variant!r}")

"""Analytic cache model — reproduces the paper's Fig. 9 (L2 miss rate) and
Fig. 10 (DRAM transactions per edge, the GAIL metric).

This container has no GPU/TPU performance counters, so we *replay the exact
vertex-value access stream* of each PageRank variant against a set-associative
LRU cache configured like the paper's GTX 1080Ti L2 (2.75 MB, 128 B lines).
Streaming arrays (colidx/rowptr/edge vals) are accounted as compulsory-miss
sequential traffic — they have no reuse and the paper's analysis treats them
as bandwidth, not locality, traffic.

The model captures precisely the effect the paper measures:

* ``base``  — per-edge random reads ``contributions[src]`` over the full
  vertex range (thrashes when |V|·4B ≫ cache) + sequential ``sums`` writes.
* ``cb``    — reads confined per block (hit) but per-block *sparse global*
  writes of partials → repeated traffic ∝ num_blocks.
* ``tocab`` — confined reads + dense compacted partial writes + one
  sequential reduction pass (reads partials, writes sums).
* ``fused`` — the fused TOCAB pipeline: confined reads only; partials
  accumulate in a fast-memory-resident tile, so the partial write/read
  traffic terms vanish and the result spills once, sequentially.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Iterable

import numpy as np

from repro.obs.metrics import registry as _obs
from .graph import Graph
from .partition import build_blocked

__all__ = ["CacheConfig", "CacheSim", "simulate_pagerank_variant", "GAIL_VARIANTS"]

GAIL_VARIANTS = ("base", "cb", "tocab", "fused")


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    capacity_bytes: int = int(2.75 * 1024 * 1024)  # GTX 1080Ti L2
    line_bytes: int = 128
    ways: int = 16

    @property
    def num_sets(self) -> int:
        return max(1, self.capacity_bytes // (self.line_bytes * self.ways))


class CacheSim:
    """Set-associative LRU cache simulator over byte addresses."""

    def __init__(self, cfg: CacheConfig):
        self.cfg = cfg
        self.sets = [OrderedDict() for _ in range(cfg.num_sets)]
        self.accesses = 0
        self.misses = 0
        self.writebacks = 0

    def access_lines(self, lines: Iterable[int], write: bool = False):
        ways = self.cfg.ways
        nsets = self.cfg.num_sets
        for line in lines:
            self.accesses += 1
            s = self.sets[line % nsets]
            if line in s:
                s.move_to_end(line)
                if write:
                    s[line] = True
            else:
                self.misses += 1
                if len(s) >= ways:
                    _, dirty = s.popitem(last=False)
                    if dirty:
                        self.writebacks += 1
                s[line] = write

    def access_array(self, base: int, idx: np.ndarray, elem_bytes: int = 4, write=False):
        lines = (base + idx.astype(np.int64) * elem_bytes) // self.cfg.line_bytes
        self.access_lines(lines.tolist(), write=write)

    def access_sequential(self, base: int, count: int, elem_bytes: int = 4, write=False):
        nbytes = count * elem_bytes
        lo = base // self.cfg.line_bytes
        hi = (base + max(nbytes - 1, 0)) // self.cfg.line_bytes
        self.access_lines(range(lo, hi + 1), write=write)

    @property
    def miss_rate(self) -> float:
        return self.misses / max(self.accesses, 1)

    @property
    def dram_transactions(self) -> int:
        return self.misses + self.writebacks


def simulate_pagerank_variant(
    g: Graph,
    variant: str,
    cfg: CacheConfig = CacheConfig(),
    block_size: int | None = None,
) -> dict:
    """Replay one PR-pull iteration's vertex-value accesses; return metrics.

    Only the *cache-relevant* stream is replayed through the LRU model (the
    contributions/sums/partials arrays); purely-streaming CSR index traffic
    is added analytically to DRAM transactions (it always misses)."""
    sim = CacheSim(cfg)
    n, m = g.n, g.m
    lb = cfg.line_bytes
    # disjoint virtual address spaces
    A_CONTRIB = 0
    A_SUMS = 1 << 40
    A_PART = 2 << 40

    src, dst = g.edges()
    stream_lines = 0  # compulsory sequential traffic (colidx + rowptr)
    stream_lines += (m * 4) // lb + 1  # colidx
    stream_lines += ((n + 1) * 4) // lb + 1  # rowptr

    if variant == "base":
        # pull: for each dst in order, read contributions[src] (random),
        # write sums[dst] (sequential).
        order = np.argsort(dst, kind="stable")
        sim.access_array(A_CONTRIB, src[order])
        sim.access_sequential(A_SUMS, n, write=True)
    elif variant in ("cb", "tocab", "fused"):
        if block_size is None:
            # paper's GPU choice: block sized so the window fits L2
            block_size = max(256, cfg.capacity_bytes // 8 // 4)
        bg = build_blocked(g, block_size=block_size, direction="pull")
        wij = np.asarray(bg.window_idx)
        cij = np.asarray(bg.compact_idx)
        mask = np.asarray(bg.edge_mask)
        idmap = np.asarray(bg.id_map)
        nloc = np.asarray(bg.n_local)
        for b in range(bg.num_blocks):
            em = mask[b]
            srcs = wij[b][em] + b * bg.block_size
            sim.access_array(A_CONTRIB, srcs)  # window-confined reads
            if variant == "tocab":
                # dense partial slab writes (compacted local IDs)
                sim.access_array(A_PART + b * bg.local_budget * 4, cij[b][em], write=True)
            elif variant == "cb":
                # conventional CB: sparse *global-width* writes per block —
                # the repeated-access overhead the paper calls out.
                gdst = idmap[b][cij[b][em]]
                sim.access_array(A_SUMS, gdst, write=True)
            # fused: partials never leave the resident accumulator — no
            # partial traffic term at all.
        if variant == "tocab":
            # reduction phase: sequential read of all partials, sequential
            # write of sums (paper Fig. 5 — fully coalesced).
            total_locals = int(nloc.sum())
            sim.access_sequential(A_PART, total_locals)
            sim.access_sequential(A_SUMS, n, write=True)
            stream_lines += (total_locals * 4) // lb + 1  # id_map stream
        elif variant == "fused":
            # epilogue spill: the resident output tile is written once,
            # sequentially; id_map windows still stream in per block to
            # address the fold.
            total_locals = int(nloc.sum())
            sim.access_sequential(A_SUMS, n, write=True)
            stream_lines += (total_locals * 4) // lb + 1  # id_map stream
    else:
        raise ValueError(f"unknown variant {variant!r}")

    dram = sim.dram_transactions + stream_lines
    result = dict(
        variant=variant,
        miss_rate=sim.miss_rate,
        cache_accesses=sim.accesses,
        cache_misses=sim.misses,
        cache_writebacks=sim.writebacks,
        dram_transactions=dram,
        dram_per_edge=dram / max(m, 1),
        num_blocks=1 if variant == "base" else bg.num_blocks,
    )
    # Publish through the process-wide registry (same series the runtime
    # engines use) so a benchmark export carries the locality counters
    # alongside wall-clock — the paper's Fig. 9/10 axes, machine-readable.
    for key in ("miss_rate", "cache_accesses", "cache_misses",
                "cache_writebacks", "dram_transactions", "dram_per_edge"):
        _obs.gauge(f"cache.{key}", "analytic LRU cache model").set(
            result[key], variant=variant)
    _obs.counter("cache.simulations", "cache-model replays").inc(
        variant=variant)
    return result

"""TOCAB static 1D blocking with local-ID compaction (paper §3.1).

Pull direction = *column blocking*: edges are grouped by the block of their
**source** vertex, so the randomly-read ``contributions`` array is confined to
a fast-memory-sized contiguous window per block.  Destinations touched by a
block are compacted to dense local IDs; partial results are written to a dense
``partial_sums[local_budget]`` slab and merged in a second reduction phase.

Push direction = *row blocking*: identical code path on the transposed roles
(the paper: "the same preprocessing code works for both push and pull").

All arrays are padded to static budgets so the representation is
jit/pjit/Pallas friendly:  every block owns an identical-shape slab — this is
the TPU analogue of the paper's TWC shape regularization.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, GraphValidationError, graph_fingerprint, \
    validate_graph

__all__ = ["BlockedGraph", "build_blocked", "choose_block_size"]

# Bin thresholds forwarded to repro.core.balance.make_schedule by default.
DEFAULT_BIN_THRESHOLDS = (4.0, 32.0)

# Identity elements per reduction op (used to neutralize padded edge slots).
REDUCE_IDENTITY = {
    "sum": 0.0,
    "min": float("inf"),
    "max": float("-inf"),
}


def _roundup(x: int, to: int) -> int:
    return int(math.ceil(max(x, 1) / to) * to)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    """TOCAB blocked-CSR representation (device-ready, static shapes).

    Role of the two index planes depends on ``direction``:

    =============  =======================  =======================
    field          pull (column blocking)   push (row blocking)
    =============  =======================  =======================
    window_idx     src − block·B (gather    dst − block·B (scatter
                   side, contiguous VMEM    side, contiguous window
                   window of values)        of the output)
    compact_idx    dst local ID (scatter    src local ID (gather
                   side → partial_sums)     side → block_contrib)
    id_map         local dst → global dst   local src → global src
    =============  =======================  =======================
    """

    # --- static metadata (aux data, not traced) ---
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    direction: str = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))
    num_blocks: int = dataclasses.field(metadata=dict(static=True))
    edge_budget: int = dataclasses.field(metadata=dict(static=True))
    local_budget: int = dataclasses.field(metadata=dict(static=True))
    # --- traced arrays ---
    window_idx: jnp.ndarray  # int32[num_blocks, edge_budget]
    compact_idx: jnp.ndarray  # int32[num_blocks, edge_budget]
    edge_mask: jnp.ndarray  # bool[num_blocks, edge_budget]
    id_map: jnp.ndarray  # int32[num_blocks, local_budget]  (pad = n)
    n_local: jnp.ndarray  # int32[num_blocks]
    n_edges: jnp.ndarray  # int32[num_blocks]
    edge_perm: jnp.ndarray = None  # int32[num_blocks, edge_budget] original edge id (pad = m)
    edge_vals: Optional[jnp.ndarray] = None  # f32[num_blocks, edge_budget]
    # distinct window-side vertices per block (reduction rows in push)
    n_window: Optional[jnp.ndarray] = None  # int32[num_blocks]
    # static sparsity classification (repro.core.balance.BlockSchedule);
    # static → part of the jit cache key, so per-bin dispatch is free.
    schedule: Optional[object] = dataclasses.field(
        default=None, metadata=dict(static=True))
    # structural fingerprint of the source graph (tuning-db key); static so
    # schedule="auto" can resolve a tuned plan even at trace time.
    fingerprint: Optional[str] = dataclasses.field(
        default=None, metadata=dict(static=True))

    # ------------------------------------------------------------------ #
    @property
    def num_subgraphs(self) -> int:  # paper Table 4 metric
        return self.num_blocks

    @property
    def flat_partial_size(self) -> int:
        return self.num_blocks * self.local_budget

    def padding_fraction(self) -> float:
        return 1.0 - self.m / (self.num_blocks * self.edge_budget)

    def window_lo(self) -> jnp.ndarray:
        """Per-block start of the contiguous window (int32[num_blocks])."""
        return jnp.arange(self.num_blocks, dtype=jnp.int32) * self.block_size


def choose_block_size(
    n: int,
    value_bytes: int = 4,
    fast_mem_bytes: int = 4 * 1024 * 1024,
    align: int = 128,
) -> int:
    """Pick the source-window size so the value window fits the fast-memory
    budget.  GPU paper: 256-vertex blocks for a 2.75 MB L2 shared by the whole
    chip; TPU: VMEM is per-core and software managed, we default to a 4 MB
    window (→ up to 2²⁰ fp32 values), yielding *far fewer* subgraphs — the
    paper's own argument against CuSha's tiny shards, taken further."""
    bs = min(max(align, fast_mem_bytes // value_bytes), max(n, align))
    return _roundup(bs, align)


def build_blocked(
    g: Graph,
    block_size: Optional[int] = None,
    direction: str = "pull",
    pad_edges_to: int = 128,
    pad_locals_to: int = 8,
    fast_mem_bytes: int = 4 * 1024 * 1024,
    classify: bool = True,
    bin_thresholds: Union[Tuple[float, float], str] = DEFAULT_BIN_THRESHOLDS,
    validate: Optional[str] = None,
) -> BlockedGraph:
    """Host-side TOCAB preprocessing (paper §3.1 phase 1).

    ``direction='pull'`` blocks by source range; ``'push'`` by destination
    range.  Edges within a block are sorted by their *scatter-side* index so
    accumulation is segment-contiguous.

    ``classify=True`` (default) also bins every block by edges-per-row
    sparsity (``repro.core.balance``) — the blocked subgraphs are much
    sparser than the original graph, so the balanced engines dispatch each
    bin to a matched execution strategy.  ``bin_thresholds`` may be an
    ``(lo, hi)`` pair of edges-per-row cutoffs or ``'auto'`` (per-graph
    terciles).

    ``validate="cheap"`` / ``"full"`` runs CSR validation on ``g`` first
    (:func:`repro.core.graph.validate_graph`) — malformed inputs fail with a
    structured :class:`~repro.core.graph.GraphValidationError` instead of
    corrupting the blocked slabs.  Independently of ``validate``, padded
    slab sizes are always checked against int32 addressing.
    """
    assert direction in ("pull", "push")
    if validate is not None:
        validate_graph(g, level=validate)
    if block_size is None:
        block_size = choose_block_size(g.n, fast_mem_bytes=fast_mem_bytes)
    src, dst = g.edges()
    src = src.astype(np.int64)
    dst = dst.astype(np.int64)
    if direction == "pull":
        window_g, compact_g = src, dst  # gather from src window, compact dst
    else:
        window_g, compact_g = dst, src  # scatter to dst window, compact src

    num_blocks = max(1, -(-g.n // block_size))
    blk = window_g // block_size

    # Sort edges by (block, compact-global) — gives blocked CSR with the
    # compacted side contiguous, which both makes local-ID assignment a
    # run-length pass and keeps the scatter side sorted for the kernels.
    order = np.lexsort((compact_g, blk))
    blk, window_g, compact_g = blk[order], window_g[order], compact_g[order]
    vals = None if g.vals is None else g.vals[order]

    edge_counts = np.bincount(blk, minlength=num_blocks).astype(np.int64)
    edge_budget = _roundup(int(edge_counts.max(initial=1)), pad_edges_to)

    # Local-ID compaction: within each block, unique compact-side vertices in
    # sorted order get ids 0..n_local-1 (paper Fig. 4).
    new_run = np.ones(blk.shape[0], dtype=bool)
    if blk.shape[0] > 1:
        new_run[1:] = (blk[1:] != blk[:-1]) | (compact_g[1:] != compact_g[:-1])
    run_id = np.cumsum(new_run) - 1  # global run index
    block_start_run = np.zeros(num_blocks + 1, dtype=np.int64)
    # run index at the first edge of each block:
    first_edge = np.cumsum(np.concatenate([[0], edge_counts]))[:-1]
    has_edges = edge_counts > 0
    block_start_run[:-1][has_edges] = run_id[first_edge[has_edges]]
    local_id = run_id - np.repeat(block_start_run[:-1], edge_counts)
    n_local = np.zeros(num_blocks, dtype=np.int64)
    if blk.shape[0]:
        np.maximum.at(n_local, blk, local_id + 1)
    local_budget = _roundup(int(n_local.max(initial=1)), pad_locals_to)

    # Padded slabs are flattened and indexed with int32 downstream (the
    # phase-3 segment reduce, the Pallas kernels' id maps) — overflow here
    # would wrap silently at runtime, so it is always a hard error.
    int32_max = np.iinfo(np.int32).max
    for what, size in (("edge", num_blocks * edge_budget),
                       ("partial", num_blocks * local_budget)):
        if size > int32_max:
            raise GraphValidationError(
                "budget_overflow",
                f"flat {what} slab has {size} entries "
                f"(num_blocks={num_blocks}), exceeding int32 addressing")

    # --- fill padded slabs ---
    shape_e = (num_blocks, edge_budget)
    window_idx = np.zeros(shape_e, dtype=np.int32)
    compact_idx = np.zeros(shape_e, dtype=np.int32)
    edge_mask = np.zeros(shape_e, dtype=bool)
    edge_perm = np.full(shape_e, g.m, dtype=np.int32)
    edge_vals = None if vals is None else np.zeros(shape_e, dtype=np.float32)
    id_map = np.full((num_blocks, local_budget), g.n, dtype=np.int32)

    slot = np.arange(blk.shape[0]) - np.repeat(first_edge, edge_counts)
    window_idx[blk, slot] = (window_g - blk * block_size).astype(np.int32)
    compact_idx[blk, slot] = local_id.astype(np.int32)
    edge_mask[blk, slot] = True
    edge_perm[blk, slot] = order.astype(np.int32)  # original edge index
    if edge_vals is not None:
        edge_vals[blk, slot] = vals
    id_map[blk, local_id] = compact_g.astype(np.int32)

    # Distinct window-side vertices per block — the reduction-row count of
    # the push direction (pull reduces over the compacted side, n_local).
    n_window = np.zeros(num_blocks, dtype=np.int64)
    if blk.shape[0]:
        pair = np.unique(blk * np.int64(g.n + 1) + window_g)
        np.add.at(n_window, (pair // (g.n + 1)).astype(np.int64), 1)

    schedule = None
    if classify:
        from .balance import make_schedule  # deferred import (cycle-free)

        rows = n_local if direction == "pull" else n_window
        schedule = make_schedule(edge_counts, rows, thresholds=bin_thresholds,
                                 n_compact_rows=n_local)

    return BlockedGraph(
        n=g.n,
        m=g.m,
        direction=direction,
        block_size=int(block_size),
        num_blocks=int(num_blocks),
        edge_budget=int(edge_budget),
        local_budget=int(local_budget),
        window_idx=jnp.asarray(window_idx),
        compact_idx=jnp.asarray(compact_idx),
        edge_mask=jnp.asarray(edge_mask),
        id_map=jnp.asarray(id_map),
        n_local=jnp.asarray(n_local, jnp.int32),
        n_edges=jnp.asarray(edge_counts, jnp.int32),
        edge_perm=jnp.asarray(edge_perm),
        edge_vals=None if edge_vals is None else jnp.asarray(edge_vals),
        n_window=jnp.asarray(n_window, jnp.int32),
        schedule=schedule,
        fingerprint=graph_fingerprint(g),
    )

"""TOCAB execution engines (paper §3.1 phases 2+3) and baselines.

Three engines, all pure-JAX (the Pallas fast path lives in
``repro.kernels.tocab_spmm`` and is numerically identical):

* :func:`baseline_pull` / :func:`baseline_push` — flat edge-centric
  segment-reduce over the *global* vertex arrays.  This is the paper's
  "Base" configuration: random reads of ``values[src]`` span all of HBM.
* :func:`cb_pull` — conventional cache blocking (paper's "CB" bar):
  edges are processed block-by-block but partials are written at *global*
  width (no local-ID compaction) → repeated sparse accesses to ``sums``.
* :func:`tocab_pull` / :func:`tocab_push` — the paper's contribution:
  blocked gather confined to a fast-memory window + dense compacted
  partials + a separate coalesced reduction phase.

All engines support ``sum`` / ``min`` / ``max`` semirings so that PageRank,
SpMV (sum×mul), BFS/SSSP (min-plus) and frontier propagation (max/or) share
one code path — this is the framework's "programmers only write pull/push
operators" surface (paper §3.3 last paragraph).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.obs.metrics import registry as _obs
from .graph import DeviceGraph
from .partition import REDUCE_IDENTITY, BlockedGraph

__all__ = [
    "segment_reduce",
    "resolve_schedule",
    "resolve_impl",
    "baseline_pull",
    "baseline_push",
    "cb_pull",
    "tocab_pull",
    "tocab_push",
    "tocab_pull_partials",
    "tocab_edge_reduce",
    "blocked_edge_values",
    "tocab_gather_src",
    "reduce_partials",
    "timed",
]


def _record_engine(engine: str, direction: str, blocks: int, edges: int):
    """Trace-time telemetry: fires once per (re)trace — shapes and block
    counts are static, so this is jit-safe and costs nothing at runtime.
    A growing ``engine_traces`` count on a steady workload is itself a
    signal (retrace churn)."""
    _obs.counter(
        "tocab.engine_traces", "engine (re)traces by name/direction"
    ).inc(engine=engine, direction=direction)
    _obs.gauge("tocab.blocks", "subgraphs per blocked engine trace").set(
        blocks, engine=engine)
    _obs.gauge("tocab.edges", "edges per engine trace").set(
        edges, engine=engine)


def _block_tree(out):
    """``block_until_ready`` over an arbitrary engine return value: arrays,
    tuples/dicts of arrays, or leaves without the method (ints, numpy)."""
    return jax.tree_util.tree_map(
        lambda leaf: leaf.block_until_ready()
        if hasattr(leaf, "block_until_ready") else leaf,
        out,
    )


def timed(engine_fn, graph, *args, engine: str = None, **kw):
    """Synchronously run one engine call, recording wall time and edges/s.

    ``graph`` is the DeviceGraph / BlockedGraph first argument; edges come
    from its static ``m``.  The engine may return a bare array or any pytree
    (e.g. ``(rank, iters)``) — every leaf is blocked on before the clock
    stops.  Returns the (blocked-until-ready) result."""
    import time

    name = engine or getattr(engine_fn, "__name__", "engine")
    t0 = time.perf_counter()
    out = _block_tree(engine_fn(graph, *args, **kw))
    dt = time.perf_counter() - t0
    _obs.histogram("tocab.call_seconds", "engine wall time").observe(
        dt, engine=name)
    _obs.gauge("tocab.edges_per_s", "engine throughput").set(
        graph.m / max(dt, 1e-12), engine=name)
    return out

_SEG_FNS = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
}


def segment_reduce(vals, ids, num_segments: int, reduce: str, sorted_ids: bool = False):
    fn = _SEG_FNS[reduce]
    return fn(
        vals,
        ids,
        num_segments=num_segments,
        indices_are_sorted=sorted_ids,
    )


def _edge_messages(values, src_ids, edge_vals, mask, reduce, combine):
    """Gather per-edge messages and neutralize padding with the identity."""
    msgs = jnp.take(values, src_ids, axis=0, mode="fill", fill_value=0)
    if edge_vals is not None:
        while edge_vals.ndim < msgs.ndim:
            edge_vals = edge_vals[..., None]
    if combine is not None:
        msgs = combine(msgs, edge_vals)
    elif edge_vals is not None:
        msgs = msgs * edge_vals
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], msgs.dtype)
    if msgs.ndim > mask.ndim:
        mask = mask[..., None]
    return jnp.where(mask, msgs, ident)


# ====================================================================== #
# Baseline (flat, non-blocked) engines
# ====================================================================== #
@partial(jax.jit, static_argnames=("reduce", "combine"))
def baseline_pull(
    dg: DeviceGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
):
    """out[dst] = ⊕_{(src,dst)∈E} values[src] (⊗ edge_val).

    Flat segment reduce by destination — the unblocked hand-optimized
    reference (random reads of ``values`` span the full array)."""
    _record_engine("baseline_pull", "pull", 1, dg.m)
    mask = jnp.ones(dg.src.shape, dtype=bool)
    msgs = _edge_messages(values, dg.src, dg.vals, mask, reduce, combine)
    return segment_reduce(msgs, dg.dst, dg.n, reduce)


@partial(jax.jit, static_argnames=("reduce", "combine"))
def baseline_push(
    dg: DeviceGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
):
    """Push direction: scatter values[src] to every out-neighbour.  On TPU
    there are no atomics — the scatter is realized as a segment reduce, i.e.
    push ≡ pull with the read side sequential (src-sorted edges)."""
    _record_engine("baseline_push", "push", 1, dg.m)
    mask = jnp.ones(dg.src.shape, dtype=bool)
    msgs = _edge_messages(values, dg.src, dg.vals, mask, reduce, combine)
    return segment_reduce(msgs, dg.dst, dg.n, reduce)


# ====================================================================== #
# Conventional cache blocking (no compaction) — the paper's CB strawman
# ====================================================================== #
@partial(jax.jit, static_argnames=("reduce", "combine"))
def cb_pull(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
):
    """Column blocking only: gathers are window-confined but every block
    writes partials at global width (repeated sparse access to ``sums``)."""
    assert bg.direction == "pull"
    _record_engine("cb_pull", "pull", bg.num_blocks, bg.m)
    src_global = bg.window_idx + bg.window_lo()[:, None]
    msgs = _edge_messages(values, src_global, bg.edge_vals, bg.edge_mask, reduce, combine)
    # id_map lookup per edge: id_map[b, compact_idx[b,e]]
    dst_global = jnp.take_along_axis(bg.id_map, bg.compact_idx, axis=1)
    dst_global = jnp.where(bg.edge_mask, dst_global, bg.n)

    def body(carry, xs):
        msgs_b, dst_b = xs
        out = segment_reduce(msgs_b, dst_b, bg.n + 1, reduce)[:-1]
        if reduce == "sum":
            carry = carry + out
        elif reduce == "min":
            carry = jnp.minimum(carry, out)
        else:
            carry = jnp.maximum(carry, out)
        return carry, None

    init = jnp.full(
        (bg.n,) + msgs.shape[2:],
        REDUCE_IDENTITY[reduce],
        msgs.dtype,
    )
    out, _ = jax.lax.scan(body, init, (msgs, dst_global))
    return out


# ====================================================================== #
# TOCAB — blocked + compacted (the paper's contribution)
# ====================================================================== #
def tocab_pull_partials(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
):
    """Phase 2 (subgraph processing, Alg. 4): per-block dense partial slabs.

    Returns ``partials`` of shape (num_blocks, local_budget, *value_tail).
    Gathers hit only the block's contiguous source window; scatters hit only
    the dense local partial slab — both fast-memory resident on TPU."""
    assert bg.direction == "pull"
    src_global = bg.window_idx + bg.window_lo()[:, None]
    msgs = _edge_messages(values, src_global, bg.edge_vals, bg.edge_mask, reduce, combine)
    flat_idx = (
        bg.compact_idx + jnp.arange(bg.num_blocks, dtype=jnp.int32)[:, None] * bg.local_budget
    )
    tail = msgs.shape[2:]
    partials = segment_reduce(
        msgs.reshape((-1,) + tail),
        flat_idx.reshape(-1),
        bg.flat_partial_size,
        reduce,
    )
    return partials.reshape((bg.num_blocks, bg.local_budget) + tail)


def reduce_partials(bg: BlockedGraph, partials: jnp.ndarray, reduce: str = "sum"):
    """Phase 3 (accumulation, paper Fig. 5): merge dense per-block partials
    into the global result.  One flat segment reduce keyed by ``id_map`` —
    XLA lowers it to a vectorized single pass; on a sharded mesh the same op
    becomes a reduce-scatter over the destination axis."""
    tail = partials.shape[2:]
    out = segment_reduce(
        partials.reshape((-1,) + tail),
        bg.id_map.reshape(-1),
        bg.n + 1,  # padded id_map entries point at segment n → dropped
        reduce,
    )
    return out[:-1]


def resolve_schedule(bg, schedule: str, workload: str = "spmv") -> str:
    """``"auto"`` → the tuned plan's schedule for this graph (``repro.tune``
    DB keyed by the BlockedGraph's build-time fingerprint — static, so this
    is safe even at jit trace time), anything else passes through."""
    if schedule != "auto":
        return schedule
    from repro.tune.plan import resolve_schedule as _resolve

    return _resolve(bg, workload=workload)


def resolve_impl(bg, impl: str, workload: str = "spmv") -> str:
    """``"auto"`` → the tuned plan's engine implementation (``"slab"`` or
    ``"fused"``) for this graph, anything else passes through.  Like
    :func:`resolve_schedule` this keys on the BlockedGraph's static
    fingerprint, so it is safe at jit trace time."""
    if impl != "auto":
        return impl
    from repro.tune.plan import resolve_impl as _resolve

    return _resolve(bg, workload=workload)


def _reconcile_fused(schedule: str, impl: str,
                     schedule_arg: str, impl_arg: str):
    """``fused`` × ``balanced`` is not a valid pairing — the fused pipeline
    runs every block through one resident-accumulator kernel (its bin
    awareness is a visit *order*, not per-bin strategies).  Whichever side
    the tuner picked (``"auto"``) yields; an explicit conflict is an
    error."""
    if impl == "fused" and schedule == "balanced":
        if impl_arg == "auto":
            return schedule, "slab"
        if schedule_arg == "auto":
            return "uniform", impl
        raise ValueError(
            "impl='fused' is incompatible with schedule='balanced' — use "
            "schedule='uniform' (or 'auto') with the fused pipeline")
    return schedule, impl


def _slab_epilogue(out, reduce: str, epilogue):
    """Per-vertex apply step on the slab path: the same affine expression
    the fused kernels bake into their final block visit, applied as a
    separate (XLA-fused) pass — keeps the two impls bit-identical."""
    if epilogue is None:
        return out
    if reduce != "sum":
        raise ValueError(
            f"epilogue fusion is affine (out*mul+add) — only the sum "
            f"semiring supports it, got reduce={reduce!r}")
    mul, add = epilogue
    return out * mul + add


def _ladder_dispatch(engine: str, bg, ri: str, allow: bool, fused_thunk,
                     slab_thunk, reference_thunk):
    """Degradation-ladder dispatch for one engine call (see
    :mod:`repro.resilience.degrade`).  ``engine`` is the dispatch-site
    label (``tocab_pull``/``tocab_push``/``tocab_edge_reduce``);
    fingerprint-keyed verdicts make the fallback a once-per-(graph,
    engine) decision, not a per-iteration one."""
    from repro.resilience import degrade

    rungs = []
    if ri == "fused":
        rungs.append(("fused", fused_thunk))
    if ri in ("fused", "slab"):
        rungs.append(("slab", slab_thunk))
    if reference_thunk is not None:
        rungs.append(("reference", reference_thunk))
    if not rungs or ri not in ("fused", "slab", "reference"):
        raise ValueError(f"unknown impl {ri!r}")
    if ri == "reference":
        return reference_thunk()
    if not allow:
        return rungs[0][1]()
    return degrade.dispatch(engine, bg.fingerprint, rungs,
                            allow_fallback=True)


@partial(jax.jit, static_argnames=("reduce", "combine", "schedule",
                                   "dense_impl"))
def _tocab_pull_jit(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    schedule: str = "uniform",
    dense_impl: Optional[str] = None,
):
    if schedule == "balanced":
        from .balance import balanced_pull

        return balanced_pull(bg, values, reduce, combine,
                             dense_impl=dense_impl)
    if schedule != "uniform":
        raise ValueError(f"unknown schedule {schedule!r}")
    _record_engine("tocab_pull", "pull", bg.num_blocks, bg.m)
    partials = tocab_pull_partials(bg, values, reduce, combine)
    return reduce_partials(bg, partials, reduce)


def tocab_pull(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    schedule: str = "uniform",
    dense_impl: Optional[str] = None,
    impl: str = "slab",
    epilogue=None,
    allow_fallback: Optional[bool] = None,
):
    """``schedule='uniform'`` processes every block with the same segmented
    reduce; ``'balanced'`` dispatches each sparsity bin of the build-time
    :class:`~repro.core.balance.BlockSchedule` to its matched strategy;
    ``'auto'`` resolves uniform/balanced from the ``repro.tune`` tuning DB
    (falling back to uniform when this graph was never tuned).
    ``dense_impl`` forces the balanced dense-bin backend ('pallas' /
    'onehot'; default picks per backend).

    ``impl='fused'`` routes through the persistent single-kernel pipeline
    (``repro.kernels.tocab_fused``): no partial slab in HBM, bit-identical
    results; ``'auto'`` consults the tuning DB.  ``epilogue=(mul, add)``
    fuses the per-vertex apply step ``out*mul + add`` (sum semiring only) —
    the slab path applies the identical expression as a trailing pass.

    ``allow_fallback`` arms the fused→slab→reference degradation ladder
    (:mod:`repro.resilience.degrade`); default ``None`` means on for
    ``impl='auto'`` and env-gated for explicit impls."""
    from repro.resilience import chaos, degrade

    rs = resolve_schedule(bg, schedule)
    ri = resolve_impl(bg, impl)
    rs, ri = _reconcile_fused(rs, ri, schedule, impl)
    allow = degrade.fallback_allowed(impl, allow_fallback)
    if allow:
        ri = degrade.apply_verdict(bg.fingerprint, "tocab_pull", ri)

    def _fused():
        chaos.maybe_raise("kernel.tocab_fused")
        from repro.kernels.tocab_fused import fused_pull

        _record_engine("tocab_pull_fused", "pull", bg.num_blocks, bg.m)
        return fused_pull(bg, values, reduce, combine, epilogue)

    def _slab():
        if allow:
            chaos.maybe_raise("kernel.tocab_slab")
        out = _tocab_pull_jit(bg, values, reduce=reduce, combine=combine,
                              schedule=rs, dense_impl=dense_impl)
        return _slab_epilogue(out, reduce, epilogue)

    def _reference():
        # eager uniform dataflow, no jax.jit anywhere on the way down —
        # survives backend lowering/compile failures by construction
        _record_engine("tocab_pull_reference", "pull", bg.num_blocks, bg.m)
        partials = tocab_pull_partials(bg, values, reduce, combine)
        return _slab_epilogue(reduce_partials(bg, partials, reduce),
                              reduce, epilogue)

    return _ladder_dispatch("tocab_pull", bg, ri, allow, _fused, _slab,
                            _reference)


@partial(jax.jit, static_argnames=("reduce", "combine", "schedule"))
def _tocab_push_jit(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    schedule: str = "uniform",
):
    assert bg.direction == "push"
    if schedule == "balanced":
        from .balance import balanced_push

        return balanced_push(bg, values, reduce, combine)
    if schedule != "uniform":
        raise ValueError(f"unknown schedule {schedule!r}")
    return _tocab_push_uniform(bg, values, reduce, combine)


def _tocab_push_uniform(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    engine: str = "tocab_push",
):
    """Uniform push body — shared by the jitted wrapper above and the
    eager ``reference`` rung of the degradation ladder."""
    _record_engine(engine, "push", bg.num_blocks, bg.m)
    # Gather each unique source's value once per block (the data-reuse win).
    block_contrib = jnp.take(values, bg.id_map, axis=0, mode="fill", fill_value=0)
    msgs = jnp.take_along_axis(
        block_contrib,
        bg.compact_idx if block_contrib.ndim == 2 else bg.compact_idx[..., None],
        axis=1,
    )
    ev = bg.edge_vals
    if ev is not None:
        while ev.ndim < msgs.ndim:
            ev = ev[..., None]
    if combine is not None:
        msgs = combine(msgs, ev)
    elif ev is not None:
        msgs = msgs * ev
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], msgs.dtype)
    mask = bg.edge_mask if msgs.ndim == bg.edge_mask.ndim else bg.edge_mask[..., None]
    msgs = jnp.where(mask, msgs, ident)
    # Scatter into the (disjoint) per-block destination windows.
    dst_global = bg.window_idx + bg.window_lo()[:, None]
    dst_global = jnp.where(bg.edge_mask, dst_global, bg.n)
    tail = msgs.shape[2:]
    out = segment_reduce(
        msgs.reshape((-1,) + tail),
        dst_global.reshape(-1),
        bg.n + 1,
        reduce,
    )
    return out[:-1]


def tocab_push(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    schedule: str = "uniform",
    impl: str = "slab",
    epilogue=None,
    allow_fallback: Optional[bool] = None,
):
    """Push (Alg. 5): block by destination range; contributions of the few
    distinct sources of a block are fetched *once* through ``id_map``
    (block_contrib slab), then fanned out per edge; accumulation is confined
    to the block's destination window (conflict-free, no atomics on TPU).
    ``schedule`` as in :func:`tocab_pull` (including ``'auto'``); ``impl``,
    ``epilogue`` and ``allow_fallback`` as in :func:`tocab_pull` — the fused
    push visits blocks in the balance module's bin-major order (disjoint
    destination windows keep that bit-identical)."""
    from repro.resilience import chaos, degrade

    rs = resolve_schedule(bg, schedule)
    ri = resolve_impl(bg, impl)
    rs, ri = _reconcile_fused(rs, ri, schedule, impl)
    allow = degrade.fallback_allowed(impl, allow_fallback)
    if allow:
        ri = degrade.apply_verdict(bg.fingerprint, "tocab_push", ri)

    def _fused():
        chaos.maybe_raise("kernel.tocab_fused")
        from repro.kernels.tocab_fused import fused_push

        _record_engine("tocab_push_fused", "push", bg.num_blocks, bg.m)
        return fused_push(bg, values, reduce, combine, epilogue)

    def _slab():
        if allow:
            chaos.maybe_raise("kernel.tocab_slab")
        out = _tocab_push_jit(bg, values, reduce=reduce, combine=combine,
                              schedule=rs)
        return _slab_epilogue(out, reduce, epilogue)

    def _reference():
        out = _tocab_push_uniform(bg, values, reduce, combine,
                                  engine="tocab_push_reference")
        return _slab_epilogue(out, reduce, epilogue)

    return _ladder_dispatch("tocab_push", bg, ri, allow, _fused, _slab,
                            _reference)


# ====================================================================== #
# Dynamic per-edge values (GNN support): flat edge arrays → blocked slabs
# ====================================================================== #
def blocked_edge_values(bg: BlockedGraph, flat_vals: jnp.ndarray) -> jnp.ndarray:
    """Scatter flat per-edge values (original edge order) into the TOCAB
    blocked slab layout via ``edge_perm``.  Padded slots read 0."""
    return jnp.take(flat_vals, bg.edge_perm, axis=0, mode="fill", fill_value=0)


def _edge_reduce_uniform(bg: BlockedGraph, flat_edge_vals, reduce: str):
    """Uniform edge-reduce body (eager; shared by slab and reference)."""
    vals = blocked_edge_values(bg, flat_edge_vals)
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], vals.dtype)
    mask = bg.edge_mask
    while mask.ndim < vals.ndim:
        mask = mask[..., None]
    vals = jnp.where(mask, vals, ident)
    flat_idx = (
        bg.compact_idx
        + jnp.arange(bg.num_blocks, dtype=jnp.int32)[:, None] * bg.local_budget
    )
    tail = vals.shape[2:]
    partials = segment_reduce(
        vals.reshape((-1,) + tail), flat_idx.reshape(-1),
        bg.flat_partial_size, reduce,
    )
    partials = partials.reshape((bg.num_blocks, bg.local_budget) + tail)
    return reduce_partials(bg, partials, reduce)


def tocab_edge_reduce(
    bg: BlockedGraph,
    flat_edge_vals: jnp.ndarray,  # (m, ...) in original edge order
    reduce: str = "sum",
    schedule: str = "uniform",
    impl: str = "slab",
    epilogue=None,
    allow_fallback: Optional[bool] = None,
):
    """Reduce *edge* values to the compacted side (dst for pull layout)
    through the partial-slab + reduction machinery — the GNN primitive
    (edge messages → node aggregate) in TOCAB form.  ``impl`` /
    ``epilogue`` / ``allow_fallback`` as in :func:`tocab_pull`."""
    from repro.resilience import chaos, degrade

    rs = resolve_schedule(bg, schedule)
    ri = resolve_impl(bg, impl)
    schedule, ri = _reconcile_fused(rs, ri, schedule, impl)
    allow = degrade.fallback_allowed(impl, allow_fallback)
    if allow:
        ri = degrade.apply_verdict(bg.fingerprint, "tocab_edge_reduce", ri)
    if schedule not in ("uniform", "balanced"):
        raise ValueError(f"unknown schedule {schedule!r}")

    def _fused():
        chaos.maybe_raise("kernel.tocab_fused")
        from repro.kernels.tocab_fused import fused_edge_reduce

        _record_engine("tocab_edge_reduce_fused", bg.direction,
                       bg.num_blocks, bg.m)
        return fused_edge_reduce(bg, flat_edge_vals, reduce, epilogue)

    def _slab():
        if allow:
            chaos.maybe_raise("kernel.tocab_slab")
        if schedule == "balanced":
            from .balance import balanced_edge_reduce

            return _slab_epilogue(
                balanced_edge_reduce(bg, flat_edge_vals, reduce), reduce,
                epilogue)
        return _slab_epilogue(
            _edge_reduce_uniform(bg, flat_edge_vals, reduce), reduce,
            epilogue)

    def _reference():
        _record_engine("tocab_edge_reduce_reference", bg.direction,
                       bg.num_blocks, bg.m)
        return _slab_epilogue(
            _edge_reduce_uniform(bg, flat_edge_vals, reduce), reduce,
            epilogue)

    return _ladder_dispatch("tocab_edge_reduce", bg, ri, allow, _fused,
                            _slab, _reference)


def tocab_gather_src(bg: BlockedGraph, values: jnp.ndarray) -> jnp.ndarray:
    """Per-edge gather of source-side values in *original edge order* —
    window-confined reads, then permuted back via edge_perm's inverse.
    Used by GNN layers that need explicit per-edge messages."""
    assert bg.direction == "pull"
    src_global = bg.window_idx + bg.window_lo()[:, None]
    gathered = jnp.take(values, src_global, axis=0)  # (nb, eb, ...)
    tail = gathered.shape[2:]
    flat = jnp.zeros((bg.m + 1,) + tail, gathered.dtype)
    flat = flat.at[bg.edge_perm.reshape(-1)].set(
        gathered.reshape((-1,) + tail)
    )
    return flat[: bg.m]

"""CSR graph container and builders.

The host-side ``Graph`` (numpy) is the preprocessing-time representation: TOCAB
is a *static* blocking scheme, so partitioning happens on the host before any
device computation, exactly as in the paper.  ``DeviceGraph`` is the flat
edge-centric (COO + CSR) representation shipped to the device for the
*baseline* (non-blocked) engines; the blocked representation lives in
:mod:`repro.core.partition`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Graph",
    "DeviceGraph",
    "GraphValidationError",
    "from_edges",
    "validate_graph",
    "graph_fingerprint",
    "rmat_graph",
    "uniform_random_graph",
    "grid_graph",
    "to_networkx",
]

#: cap on how many colidx entries the fingerprint hashes (strided sample)
_FP_SAMPLE = 4096

#: cap on how many colidx entries level="cheap" bounds-checks (strided sample)
_VALIDATE_SAMPLE = 65536

_INT32_MAX = np.iinfo(np.int32).max


class GraphValidationError(ValueError):
    """A CSR structural invariant does not hold.

    ``check`` names the violated invariant (stable identifier, e.g.
    ``"rowptr_monotone"``), ``detail`` is a human-readable description.
    Structured so callers (tests, ingestion pipelines) can branch on the
    failure class without parsing messages."""

    def __init__(self, check: str, detail: str):
        super().__init__(f"{check}: {detail}")
        self.check = check
        self.detail = detail


def validate_graph(g: "Graph", level: str = "cheap") -> "Graph":
    """Check CSR invariants, raising :class:`GraphValidationError`.

    ``level="cheap"`` is O(n) + an O(sample) colidx bounds check: rowptr
    shape/endpoints/monotonicity, strided colidx sample in ``[0, n)``,
    edge-value length, and int32 addressability (the device engines index
    with int32).  ``level="full"`` additionally bounds-checks every colidx
    entry.  Returns ``g`` unchanged on success so calls can be chained."""
    if level not in ("cheap", "full"):
        raise ValueError(f"unknown validation level {level!r}")
    n, rowptr, colidx = g.n, np.asarray(g.rowptr), np.asarray(g.colidx)
    m = int(colidx.shape[0])
    if n < 0:
        raise GraphValidationError("n_negative", f"n={n} < 0")
    if n > _INT32_MAX or m > _INT32_MAX:
        raise GraphValidationError(
            "budget_overflow",
            f"n={n}, m={m} exceed int32 addressing used by device engines")
    if rowptr.ndim != 1 or rowptr.shape[0] != n + 1:
        raise GraphValidationError(
            "rowptr_shape",
            f"rowptr has shape {rowptr.shape}, expected ({n + 1},)")
    if m and not np.issubdtype(rowptr.dtype, np.integer):
        raise GraphValidationError(
            "rowptr_dtype", f"rowptr dtype {rowptr.dtype} is not integral")
    if int(rowptr[0]) != 0:
        raise GraphValidationError(
            "rowptr_origin", f"rowptr[0]={int(rowptr[0])}, expected 0")
    if int(rowptr[-1]) != m:
        raise GraphValidationError(
            "rowptr_total",
            f"rowptr[-1]={int(rowptr[-1])} != m={m} (len(colidx))")
    if n and np.any(np.diff(rowptr) < 0):
        bad = int(np.argmax(np.diff(rowptr) < 0))
        raise GraphValidationError(
            "rowptr_monotone",
            f"rowptr decreases at row {bad} "
            f"({int(rowptr[bad])} -> {int(rowptr[bad + 1])})")
    if g.vals is not None and np.asarray(g.vals).shape[0] != m:
        raise GraphValidationError(
            "vals_length",
            f"vals has {np.asarray(g.vals).shape[0]} entries, expected m={m}")
    if m:
        sample = colidx
        if level == "cheap" and m > _VALIDATE_SAMPLE:
            sample = colidx[:: max(1, m // _VALIDATE_SAMPLE)]
        lo, hi = int(sample.min()), int(sample.max())
        if lo < 0 or hi >= n:
            raise GraphValidationError(
                "colidx_range",
                f"colidx entries span [{lo}, {hi}], expected [0, {n})")
    return g


def _fingerprint_arrays(n: int, m: int, out_degree, colidx) -> str:
    """Canonical structural fingerprint used as the tuning-db key.

    Hashes (n, m, the full out-degree sequence, a strided colidx sample) —
    identical for a host :class:`Graph` and the :class:`DeviceGraph` built
    from it, independent of edge weights (plans key dtype separately), and
    stable across processes (no Python ``hash`` randomization)."""
    import hashlib

    h = hashlib.sha256()
    h.update(f"repro.graph/v1:{n}:{m}:".encode())
    h.update(np.ascontiguousarray(out_degree, dtype=np.int64).tobytes())
    colidx = np.ascontiguousarray(colidx, dtype=np.int32)
    stride = max(1, colidx.shape[0] // _FP_SAMPLE)
    h.update(colidx[::stride].tobytes())
    return h.hexdigest()[:16]


def graph_fingerprint(g) -> str:
    """Fingerprint of a :class:`Graph` or :class:`DeviceGraph` (see
    :func:`_fingerprint_arrays`).  DeviceGraphs built via ``from_host``
    carry it precomputed; hand-built ones are hashed on the fly."""
    fp = getattr(g, "fingerprint", None)
    if isinstance(fp, str):
        return fp
    if isinstance(g, Graph):
        return _fingerprint_arrays(g.n, g.m, g.out_degree, g.colidx)
    return _fingerprint_arrays(
        g.n, g.m, np.asarray(g.out_degree), np.asarray(g.dst))


@dataclasses.dataclass(frozen=True)
class Graph:
    """Host-side CSR graph (out-edges).  ``vals`` optional per-edge weights."""

    n: int
    rowptr: np.ndarray  # int64[n+1]
    colidx: np.ndarray  # int32[m]
    vals: Optional[np.ndarray] = None  # float32[m]

    @property
    def m(self) -> int:
        return int(self.colidx.shape[0])

    @property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.rowptr).astype(np.int32)

    @property
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.colidx, minlength=self.n).astype(np.int32)

    def edges(self) -> tuple[np.ndarray, np.ndarray]:
        """COO view: (src, dst) arrays, src-sorted."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.out_degree)
        return src, self.colidx.astype(np.int32)

    def transpose(self) -> "Graph":
        """Gᵀ — used to derive pull (in-edge) iteration and push blocking."""
        src, dst = self.edges()
        return from_edges(self.n, dst, src, vals=self.vals)

    def average_degree(self) -> float:
        return self.m / max(self.n, 1)

    def degree_histogram(self, bounds=(8, 16, 32)) -> dict:
        """Degree distribution buckets — reproduces paper Table 1."""
        deg = self.out_degree
        hist, lo = {}, 0
        for b in bounds:
            hist[f"{lo}~{b - 1}"] = float(np.mean((deg >= lo) & (deg < b)))
            lo = b
        hist[f"{lo}~"] = float(np.mean(deg >= lo))
        return hist

    def validate(self, level: str = "cheap") -> "Graph":
        """Check CSR invariants (see :func:`validate_graph`)."""
        return validate_graph(self, level=level)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Flat edge-centric device representation for the baseline engines."""

    n: int = dataclasses.field(metadata=dict(static=True))
    src: jnp.ndarray  # int32[m]  (src-sorted)
    dst: jnp.ndarray  # int32[m]
    rowptr: jnp.ndarray  # int32[n+1]
    out_degree: jnp.ndarray  # int32[n]
    in_degree: jnp.ndarray  # int32[n]
    vals: Optional[jnp.ndarray] = None
    # structural fingerprint (tuning-db key); static → usable at trace time
    fingerprint: Optional[str] = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @classmethod
    def from_host(cls, g: Graph) -> "DeviceGraph":
        src, dst = g.edges()
        return cls(
            n=g.n,
            src=jnp.asarray(src, jnp.int32),
            dst=jnp.asarray(dst, jnp.int32),
            rowptr=jnp.asarray(g.rowptr, jnp.int32),
            out_degree=jnp.asarray(g.out_degree, jnp.int32),
            in_degree=jnp.asarray(g.in_degree, jnp.int32),
            vals=None if g.vals is None else jnp.asarray(g.vals, jnp.float32),
            fingerprint=graph_fingerprint(g),
        )


def from_edges(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    vals: Optional[np.ndarray] = None,
    dedup: bool = False,
    validate: Optional[str] = None,
) -> Graph:
    """Build a CSR :class:`Graph` from COO edges.

    ``validate="cheap"`` / ``"full"`` runs :func:`validate_graph` on the
    result (and raises :class:`GraphValidationError` on malformed COO input
    instead of an assertion)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise GraphValidationError(
            "coo_shape", f"src shape {src.shape} != dst shape {dst.shape}")
    if src.size and validate is not None:
        lo = min(int(src.min()), int(dst.min()))
        hi = max(int(src.max()), int(dst.max()))
        if lo < 0 or hi >= n:
            raise GraphValidationError(
                "coo_range",
                f"edge endpoints span [{lo}, {hi}], expected [0, {n})")
    if src.size:
        assert src.min() >= 0 and src.max() < n, "src out of range"
        assert dst.min() >= 0 and dst.max() < n, "dst out of range"
    if dedup and src.size:
        key = src * n + dst
        _, idx = np.unique(key, return_index=True)
        src, dst = src[idx], dst[idx]
        vals = None if vals is None else np.asarray(vals)[idx]
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    if vals is not None:
        vals = np.asarray(vals, dtype=np.float32)[order]
    rowptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(rowptr, src + 1, 1)
    rowptr = np.cumsum(rowptr)
    g = Graph(n=n, rowptr=rowptr, colidx=dst.astype(np.int32), vals=vals)
    return g if validate is None else validate_graph(g, level=validate)


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    undirected: bool = False,
    weights: bool = False,
) -> Graph:
    """R-MAT/Kronecker power-law generator (Graph500-style) — scale-free graphs
    like the paper's Kron21/Twitter suite."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for lvl in range(scale):
        r = rng.random(m)
        # quadrant probabilities (a, b, c, d)
        go_right = r >= a + c  # dst high bit
        go_down = ((r >= a) & (r < a + c)) | (r >= a + b + c)  # src high bit
        src |= go_down.astype(np.int64) << lvl
        dst |= go_right.astype(np.int64) << lvl
    # permute vertex ids to kill the locality R-MAT bakes in (paper targets
    # graphs with *poor* layouts)
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    vals = rng.random(src.shape[0], dtype=np.float32) if weights else None
    return from_edges(n, src, dst, vals=vals, dedup=True)


def uniform_random_graph(
    n: int, m: int, seed: int = 0, weights: bool = False
) -> Graph:
    """Erdős–Rényi-ish uniform random digraph."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    vals = rng.random(int(keep.sum()), dtype=np.float32) if weights else None
    return from_edges(n, src[keep], dst[keep], vals=vals, dedup=True)


def grid_graph(rows: int, cols: int) -> Graph:
    """2D grid digraph (right+down edges) — a *good-locality* graph, the
    Hollywood-analogue control for the paper's claim that GraphCage causes
    only trivial slowdown on graphs that already have good layouts."""
    n = rows * cols
    ids = np.arange(n).reshape(rows, cols)
    src = np.concatenate([ids[:, :-1].ravel(), ids[:-1, :].ravel()])
    dst = np.concatenate([ids[:, 1:].ravel(), ids[1:, :].ravel()])
    return from_edges(n, src, dst)


def to_networkx(g: Graph):
    import networkx as nx

    G = nx.DiGraph()
    G.add_nodes_from(range(g.n))
    src, dst = g.edges()
    if g.vals is not None:
        G.add_weighted_edges_from(zip(src.tolist(), dst.tolist(), g.vals.tolist()))
    else:
        G.add_edges_from(zip(src.tolist(), dst.tolist()))
    return G

"""Sparsity-aware load balancing for TOCAB subgraphs (paper §load-balancing).

GraphCage's integration argument: cache blocking only pays off when it is
*coordinated with load balancing* — blocked subgraphs are much sparser than
the original graph (paper Table 1), so a one-size-fits-all edge mapping
wastes the cache wins.  Following Gunrock's per-frontier strategy selection,
we classify every TOCAB block **once, at build time**, by its edges-per-row
density and dispatch each bin to a matched execution strategy:

==========  =========================  =====================================
bin         edges/row                  strategy
==========  =========================  =====================================
``sparse``  < ``thresholds[0]``        row-per-lane segmented reduce
                                       (sorted segment ids, one lane per
                                       compacted row — short segments)
``medium``  < ``thresholds[1]``        Merrill-style chunked segmented scan
                                       (``lax.scan`` over edge chunks with a
                                       running-segment carry)
``dense``   ≥ ``thresholds[1]``        tile kernel — the Pallas
                                       ``tocab_spmm`` bin-aware grid on TPU,
                                       or a chunked one-hot matmul (MXU
                                       shape) elsewhere
==========  =========================  =====================================

The classification is carried on :class:`~repro.core.partition.BlockedGraph`
as a static :class:`BlockSchedule` (hashable → part of the jit cache key),
so dispatch costs nothing at runtime: each bin's block subset is a Python
tuple and the per-bin computations are ordinary traced subgraph gathers.

Every engine records per-bin block/edge counters into ``repro.obs`` at
trace time; the ``fig8_balance`` benchmark times the bins individually.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import registry as _obs

from .partition import REDUCE_IDENTITY, BlockedGraph

__all__ = [
    "BIN_NAMES",
    "DEFAULT_THRESHOLDS",
    "BlockSchedule",
    "UNWEIGHTED",
    "make_schedule",
    "require_schedule",
    "balanced_pull_partials",
    "balanced_pull",
    "balanced_push",
    "balanced_edge_reduce",
    "bin_pull_partials",
    "default_dense_impl",
]

BIN_SPARSE, BIN_MEDIUM, BIN_DENSE = 0, 1, 2
BIN_NAMES = ("sparse", "medium", "dense")

#: edges-per-row cutoffs (sparse < t0 ≤ medium < t1 ≤ dense).  Defaults match
#: the CPU-scale suite: rows shorter than a VPU sublane stay on the segmented
#: reduce; rows long enough to amortize a tile matmul go dense.
DEFAULT_THRESHOLDS = (4.0, 32.0)

_OPS = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}


def UNWEIGHTED(msgs, edge_vals):
    """Sentinel ``combine`` that ignores edge values (PageRank on weighted
    graphs).  Engines recognize it by identity, which keeps the dense tile
    path eligible (generic callables force the scan fallback)."""
    return msgs


@dataclasses.dataclass(frozen=True)
class BlockSchedule:
    """Static sparsity classification of TOCAB blocks (hashable).

    ``bins[b]`` is the bin id (0=sparse, 1=medium, 2=dense) of block ``b``;
    the per-bin aggregates are precomputed host-side so observability never
    touches traced arrays.
    """

    thresholds: Tuple[float, float]
    bins: Tuple[int, ...]
    blocks_per_bin: Tuple[int, int, int]
    edges_per_bin: Tuple[int, int, int]
    rows_per_bin: Tuple[int, int, int]
    # max reduction rows of any single block in the bin (8-aligned) — the
    # bin-local partial-slab width.  Dense bins have few distinct rows per
    # block, so their tile scatters shrink from the global local_budget to
    # this much smaller static width: the scheduling win in shape form.
    row_budget_per_bin: Tuple[int, int, int] = (0, 0, 0)
    # max *compact-side* rows (n_local) of any block in the bin, 8-aligned.
    # This bounds compact_idx — the scatter target of the pull partials and
    # of balanced_edge_reduce.  For pull layouts it equals row_budget_per_bin
    # (the classification rows *are* n_local); for push the classification
    # rows are the window side (n_window), which says nothing about
    # compact_idx — sizing the edge-reduce slab from it corrupts results.
    compact_budget_per_bin: Tuple[int, int, int] = (0, 0, 0)

    @property
    def num_blocks(self) -> int:
        return len(self.bins)

    def blocks_in(self, bin_id: int) -> Tuple[int, ...]:
        return tuple(b for b, v in enumerate(self.bins) if v == bin_id)

    def summary(self) -> dict:
        return {
            name: {
                "blocks": self.blocks_per_bin[i],
                "edges": self.edges_per_bin[i],
                "rows": self.rows_per_bin[i],
            }
            for i, name in enumerate(BIN_NAMES)
        }


def make_schedule(
    n_edges: Sequence[int],
    n_rows: Sequence[int],
    thresholds: Union[Tuple[float, float], str] = DEFAULT_THRESHOLDS,
    n_compact_rows: Optional[Sequence[int]] = None,
) -> BlockSchedule:
    """Classify blocks by edges-per-row (host-side, build time).

    ``n_rows`` is the reduction-side row count of each block: compacted
    locals for pull, window vertices for push.  ``n_compact_rows`` is the
    compact-side count (``n_local``) when it differs from ``n_rows`` — push
    layouts must pass it so ``compact_budget_per_bin`` bounds ``compact_idx``
    rather than the window.  ``thresholds='auto'`` picks per-graph terciles
    of the observed edges-per-row distribution.
    """
    e = np.asarray(n_edges, dtype=np.float64)
    r = np.maximum(np.asarray(n_rows, dtype=np.float64), 1.0)
    epr = e / r
    if isinstance(thresholds, str):
        if thresholds != "auto":
            raise ValueError(f"unknown thresholds mode {thresholds!r}")
        live = epr[e > 0]
        if live.size == 0:
            lo, hi = DEFAULT_THRESHOLDS
        else:
            lo = float(np.quantile(live, 1 / 3))
            hi = max(float(np.quantile(live, 2 / 3)), lo + 1e-9)
    else:
        lo, hi = float(thresholds[0]), float(thresholds[1])
        if not lo <= hi:
            raise ValueError(f"thresholds must be ascending, got {(lo, hi)}")
    bins = np.where(epr < lo, BIN_SPARSE, np.where(epr < hi, BIN_MEDIUM, BIN_DENSE))
    bins[e == 0] = BIN_SPARSE  # empty blocks ride the cheapest path
    rows = np.asarray(n_rows, dtype=np.int64)
    compact = (
        rows if n_compact_rows is None
        else np.asarray(n_compact_rows, dtype=np.int64)
    )

    def per_bin(arr):
        return tuple(int(arr[bins == b].sum()) for b in range(3))

    def budget(arr, b):
        sel = arr[bins == b]
        top = int(sel.max()) if sel.size else 0
        return max(8, -(-top // 8) * 8)

    return BlockSchedule(
        thresholds=(lo, hi),
        bins=tuple(int(b) for b in bins),
        blocks_per_bin=tuple(int((bins == b).sum()) for b in range(3)),
        edges_per_bin=per_bin(e),
        rows_per_bin=per_bin(rows),
        row_budget_per_bin=tuple(budget(rows, b) for b in range(3)),
        compact_budget_per_bin=tuple(budget(compact, b) for b in range(3)),
    )


def require_schedule(bg: BlockedGraph) -> BlockSchedule:
    if bg.schedule is None:
        raise ValueError(
            "BlockedGraph carries no BlockSchedule — rebuild with "
            "build_blocked(..., classify=True) (the default) or attach one "
            "via dataclasses.replace(bg, schedule=make_schedule(...))."
        )
    return bg.schedule


def fused_block_order(bg: BlockedGraph) -> Tuple[int, ...]:
    """Bin-major visit order for the fused engines: dense → medium → sparse.

    The fused pipeline streams blocks back-to-back through one resident
    accumulator, so the heavy (dense) blocks go first — their gather windows
    are issued while the prefetch queue is still deep, and the short sparse
    tail can't leave the pipeline draining behind a late straggler.  Only
    valid where block order cannot change results: push (disjoint destination
    windows) always; pull only for order-insensitive semirings (min/max).
    """
    sched = require_schedule(bg)
    return (sched.blocks_in(BIN_DENSE) + sched.blocks_in(BIN_MEDIUM)
            + sched.blocks_in(BIN_SPARSE))


def default_dense_impl() -> str:
    """Pallas tile kernel on TPU; chunked one-hot matmul elsewhere (the
    interpret-mode Pallas path pads features to the 128 lane width, which is
    pure overhead off-TPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "onehot"


def _compact_budget(sched: BlockSchedule, bin_id: int, local_budget: int) -> int:
    """Static slab width for reductions over ``compact_idx`` — the bin's
    compact-side budget, falling back to the classification-row budget
    (identical for pull) and then the global ``local_budget`` for
    hand-built schedules that carry neither."""
    rb = sched.compact_budget_per_bin[bin_id] or sched.row_budget_per_bin[bin_id]
    return min(rb or local_budget, local_budget)


def _record_bins(bg: BlockedGraph, direction: str, engine: str):
    """Trace-time per-bin telemetry (static facts — jit-safe, free at run)."""
    sched = bg.schedule
    if sched is None:
        return
    for i, name in enumerate(BIN_NAMES):
        _obs.counter(
            "tocab.balance.bin_traces", "balanced-engine traces by bin"
        ).inc(bin=name, direction=direction, engine=engine)
        _obs.gauge("tocab.balance.bin_blocks", "blocks per sparsity bin").set(
            sched.blocks_per_bin[i], bin=name, direction=direction)
        _obs.gauge("tocab.balance.bin_edges", "edges per sparsity bin").set(
            sched.edges_per_bin[i], bin=name, direction=direction)


# ====================================================================== #
# Shared subset helpers
# ====================================================================== #
def _take_blocks(bg: BlockedGraph, ids: Tuple[int, ...]):
    idx = jnp.asarray(ids, jnp.int32)
    ev = None if bg.edge_vals is None else jnp.take(bg.edge_vals, idx, axis=0)
    return (
        jnp.take(bg.window_idx, idx, axis=0),
        jnp.take(bg.compact_idx, idx, axis=0),
        jnp.take(bg.edge_mask, idx, axis=0),
        ev,
        idx,
    )


def _pick_chunk(edge_budget: int, chunk: int) -> int:
    chunk = max(1, min(chunk, edge_budget))
    while edge_budget % chunk:
        chunk //= 2
    return chunk


# ====================================================================== #
# Pull-layout reduction strategies (reduce blocked messages over compact_idx)
# ====================================================================== #
def _reduce_msgs_sparse(row_budget, cidx, mask, msgs, reduce):
    """Row-per-lane segmented reduce: compact ids are sorted within each
    block (build_blocked sorts edges by compact-global), so the flattened
    segment ids are globally sorted — the short-segment fast path."""
    from .tocab import segment_reduce

    k = cidx.shape[0]
    lb1 = row_budget + 1
    cidx_eff = jnp.where(mask, cidx, row_budget)  # padding → drop row
    flat = cidx_eff + jnp.arange(k, dtype=jnp.int32)[:, None] * lb1
    tail = msgs.shape[2:]
    partials = segment_reduce(
        msgs.reshape((-1,) + tail), flat.reshape(-1), k * lb1, reduce,
        sorted_ids=True,
    )
    return partials.reshape((k, lb1) + tail)[:, :row_budget]


def _reduce_msgs_scan(row_budget, cidx, mask, msgs, reduce, chunk: int = 256):
    """Merrill-style chunked segmented scan for mid-density rows.

    Edges are processed in fixed chunks under ``lax.scan``; the running
    value of the segment left open at each chunk boundary is the carry, and
    within a chunk the segmented prefix is an ``associative_scan``.  Segment
    totals are read at segment tails and scattered once per row."""
    op = _OPS[reduce]
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], msgs.dtype)
    k, eb = cidx.shape
    tail = msgs.shape[2:]
    chunk = _pick_chunk(eb, chunk)
    nch = eb // chunk

    cidx_eff = jnp.where(mask, cidx, row_budget)
    heads = jnp.concatenate(
        [jnp.ones((k, 1), bool), cidx_eff[:, 1:] != cidx_eff[:, :-1]], axis=1)

    def expand(flags):
        return flags.reshape(flags.shape + (1,) * len(tail))

    def comb(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(expand(fb), vb, op(va, vb))

    h_c = jnp.moveaxis(heads.reshape(k, nch, chunk), 1, 0)
    v_c = jnp.moveaxis(msgs.reshape((k, nch, chunk) + tail), 1, 0)

    def chunk_step(carry, xs):
        hh, vv = xs  # (k, chunk[, tail]) — one chunk of every row
        fh, fv = jax.lax.associative_scan(comb, (hh, vv), axis=1)
        # positions before the chunk's first head continue the carried segment
        out = jnp.where(expand(fh), fv, op(carry[:, None], fv))
        return out[:, -1], out

    init = jnp.full((k,) + tail, ident, msgs.dtype)
    _, scanned = jax.lax.scan(chunk_step, init, (h_c, v_c))
    scanned = jnp.moveaxis(scanned, 0, 1).reshape((k, eb) + tail)

    tails = jnp.concatenate(
        [cidx_eff[:, 1:] != cidx_eff[:, :-1], jnp.ones((k, 1), bool)], axis=1)
    write = jnp.where(tails & mask, cidx, row_budget)  # dummy row drops
    lb1 = row_budget + 1
    flat = (write + jnp.arange(k, dtype=jnp.int32)[:, None] * lb1).reshape(-1)
    slab = jnp.full((k * lb1,) + tail, ident, msgs.dtype)
    slab = slab.at[flat].set(scanned.reshape((-1,) + tail), mode="drop")
    return slab.reshape((k, lb1) + tail)[:, :row_budget]


def _reduce_msgs_onehot(row_budget, cidx, mask, msgs, chunk: int = 256):
    """Dense-bin fallback tile path: scatter expressed as chunked one-hot
    matmuls (sum semiring only) — the MXU-native shape, pure JAX.  The
    one-hot width is the *bin's* row budget, not the global local_budget:
    dense blocks compact to few distinct rows, so the matmul stays small."""
    k, eb = cidx.shape
    tail = msgs.shape[2:]
    chunk = _pick_chunk(eb, chunk)
    nch = eb // chunk
    td = 1
    for t in tail:
        td *= t
    cidx_eff = jnp.where(mask, cidx, row_budget)
    c_c = jnp.moveaxis(cidx_eff.reshape(k, nch, chunk), 1, 0)
    v_c = jnp.moveaxis(
        msgs.reshape((k, nch, chunk, td)), 1, 0)

    lb1 = row_budget + 1

    def chunk_step(acc, xs):
        cc, vv = xs  # (k, chunk), (k, chunk, td)
        onehot = (
            cc[:, :, None] == jnp.arange(lb1, dtype=jnp.int32)[None, None, :]
        ).astype(vv.dtype)
        return acc + jnp.einsum(
            "bel,bed->bld", onehot, vv,
            preferred_element_type=jnp.float32).astype(acc.dtype), None

    init = jnp.zeros((k, lb1, td), msgs.dtype)
    acc, _ = jax.lax.scan(chunk_step, init, (c_c, v_c))
    return acc[:, :row_budget].reshape((k, row_budget) + tail)


def _pull_msgs(bg, ids, values, reduce, combine):
    from .tocab import _edge_messages

    widx, cidx, mask, ev, idx = _take_blocks(bg, ids)
    src_global = widx + (idx * bg.block_size)[:, None]
    if combine is UNWEIGHTED:
        ev, combine = None, None
    msgs = _edge_messages(values, src_global, ev, mask, reduce, combine)
    return cidx, mask, msgs


def _dense_eligible(reduce: str, combine) -> bool:
    return reduce == "sum" and (combine is None or combine is UNWEIGHTED)


def bin_pull_partials(
    bg: BlockedGraph,
    bin_id: int,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    dense_impl: Optional[str] = None,
    interpret: Optional[bool] = None,
):
    """Phase-2 partials of one sparsity bin (its blocks only, in schedule
    order), at the bin's static compact-row budget: shape ``(k, budget, …)``.
    Exposed so benchmarks can time bins individually.  ``interpret`` controls
    the Pallas dense path (default: compiled on real TPU, interpret mode
    elsewhere)."""
    sched = require_schedule(bg)
    ids = sched.blocks_in(bin_id)
    if not ids:
        return None
    rb = _compact_budget(sched, bin_id, bg.local_budget)
    if bin_id == BIN_DENSE and _dense_eligible(reduce, combine):
        impl = dense_impl or default_dense_impl()

        def _onehot():
            cidx, mask, msgs = _pull_msgs(bg, ids, values, reduce, combine)
            return _reduce_msgs_onehot(rb, cidx, mask, msgs)

        if impl == "pallas":
            from repro.resilience import chaos, degrade

            def _pallas():
                chaos.maybe_raise("kernel.tocab_spmm")
                from repro.kernels.tocab_spmm.ops import tocab_spmm_partials

                itp = (interpret if interpret is not None
                       else jax.default_backend() != "tpu")
                return tocab_spmm_partials(
                    bg, values, block_ids=ids, local_budget=rb,
                    unweighted=combine is UNWEIGHTED, interpret=itp)

            # backend-picked pallas (dense_impl=None) may degrade to the
            # one-hot matmul; an explicitly requested pallas only under
            # REPRO_RESILIENCE_FALLBACK
            allow = degrade.fallback_allowed(
                "auto" if dense_impl is None else dense_impl, None)
            if allow:
                return degrade.dispatch(
                    "tocab_spmm", bg.fingerprint,
                    [("pallas", _pallas), ("onehot", _onehot)],
                    allow_fallback=True)
            return _pallas()
        return _onehot()
    cidx, mask, msgs = _pull_msgs(bg, ids, values, reduce, combine)
    if bin_id == BIN_SPARSE:
        return _reduce_msgs_sparse(rb, cidx, mask, msgs, reduce)
    return _reduce_msgs_scan(rb, cidx, mask, msgs, reduce)


def balanced_pull_partials(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    dense_impl: Optional[str] = None,
    interpret: Optional[bool] = None,
):
    """Sparsity-aware phase 2: every bin runs its matched strategy; results
    land in the same (num_blocks, local_budget, …) slab as the uniform path,
    so phase 3 (:func:`repro.core.tocab.reduce_partials`) is unchanged."""
    assert bg.direction == "pull"
    sched = require_schedule(bg)
    tail = values.shape[1:]
    dtype = values.dtype
    partials = jnp.full(
        (bg.num_blocks, bg.local_budget) + tail,
        REDUCE_IDENTITY[reduce], dtype)
    for bin_id in range(len(BIN_NAMES)):
        sub = bin_pull_partials(
            bg, bin_id, values, reduce, combine, dense_impl, interpret)
        if sub is None:
            continue
        ids = jnp.asarray(sched.blocks_in(bin_id), jnp.int32)
        # bin partials are row_budget-wide; rows beyond stay at the identity
        partials = partials.at[ids, : sub.shape[1]].set(sub.astype(dtype))
    return partials


def balanced_pull(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
    dense_impl: Optional[str] = None,
    interpret: Optional[bool] = None,
):
    """Sparsity-aware TOCAB pull — bitwise-compatible with ``tocab_pull``
    up to float reassociation (each bin reduces the same edge sets)."""
    from .tocab import reduce_partials

    _record_bins(bg, "pull", "balanced_pull")
    partials = balanced_pull_partials(
        bg, values, reduce, combine, dense_impl, interpret)
    return reduce_partials(bg, partials, reduce)


# ====================================================================== #
# Push direction: per-bin strategies over disjoint destination windows
# ====================================================================== #
def _push_msgs(bg, ids, values, reduce, combine):
    """Per-edge messages for a subset of push blocks (gather each distinct
    source once via id_map, fan out per edge) — mirrors ``tocab_push``."""
    widx, cidx, mask, ev, idx = _take_blocks(bg, ids)
    id_map = jnp.take(bg.id_map, idx, axis=0)
    block_contrib = jnp.take(values, id_map, axis=0, mode="fill", fill_value=0)
    msgs = jnp.take_along_axis(
        block_contrib,
        cidx if block_contrib.ndim == 2 else cidx[..., None],
        axis=1,
    )
    if combine is UNWEIGHTED:
        ev, combine = None, None
    if ev is not None:
        while ev.ndim < msgs.ndim:
            ev = ev[..., None]
    if combine is not None:
        msgs = combine(msgs, ev)
    elif ev is not None:
        msgs = msgs * ev
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], msgs.dtype)
    m = mask if msgs.ndim == mask.ndim else mask[..., None]
    return widx, mask, jnp.where(m, msgs, ident)


def _push_window_sparse(bg, widx, mask, msgs, reduce):
    from .tocab import segment_reduce

    k = widx.shape[0]
    tail = msgs.shape[2:]
    local_dst = jnp.where(
        mask,
        widx + jnp.arange(k, dtype=jnp.int32)[:, None] * bg.block_size,
        k * bg.block_size,
    )
    acc = segment_reduce(
        msgs.reshape((-1,) + tail), local_dst.reshape(-1),
        k * bg.block_size + 1, reduce,
    )[:-1]
    return acc.reshape((k, bg.block_size) + tail)


def _push_window_chunked(bg, widx, mask, msgs, reduce, chunk: int = 256):
    """Chunked-scan push: each ``lax.scan`` step folds one edge chunk into a
    dense per-block window accumulator (the windows are disjoint, so the
    final write-back is a pure reshape — no global scatter)."""
    from .tocab import segment_reduce

    op = _OPS[reduce]
    k, eb = widx.shape
    tail = msgs.shape[2:]
    chunk = _pick_chunk(eb, chunk)
    nch = eb // chunk
    local_dst = jnp.where(
        mask,
        widx + jnp.arange(k, dtype=jnp.int32)[:, None] * bg.block_size,
        k * bg.block_size,
    )
    d_c = jnp.moveaxis(local_dst.reshape(k, nch, chunk), 1, 0)
    v_c = jnp.moveaxis(msgs.reshape((k, nch, chunk) + tail), 1, 0)

    def chunk_step(acc, xs):
        dd, vv = xs
        part = segment_reduce(
            vv.reshape((-1,) + tail), dd.reshape(-1),
            k * bg.block_size + 1, reduce,
        )
        return op(acc, part), None

    init = jnp.full((k * bg.block_size + 1,) + tail,
                    REDUCE_IDENTITY[reduce], msgs.dtype)
    acc, _ = jax.lax.scan(chunk_step, init, (d_c, v_c))
    return acc[:-1].reshape((k, bg.block_size) + tail)


def _push_window_onehot(bg, widx, mask, msgs, chunk: int = 128):
    """Dense-bin push: chunked one-hot matmul onto the window (sum only)."""
    k, eb = widx.shape
    tail = msgs.shape[2:]
    td = 1
    for t in tail:
        td *= t
    chunk = _pick_chunk(eb, chunk)
    nch = eb // chunk
    widx_eff = jnp.where(mask, widx, bg.block_size)  # dummy row drops
    w_c = jnp.moveaxis(widx_eff.reshape(k, nch, chunk), 1, 0)
    v_c = jnp.moveaxis(msgs.reshape((k, nch, chunk, td)), 1, 0)
    bs1 = bg.block_size + 1

    def chunk_step(acc, xs):
        ww, vv = xs
        onehot = (
            ww[:, :, None] == jnp.arange(bs1, dtype=jnp.int32)[None, None, :]
        ).astype(vv.dtype)
        return acc + jnp.einsum(
            "bew,bed->bwd", onehot, vv,
            preferred_element_type=jnp.float32).astype(acc.dtype), None

    init = jnp.zeros((k, bs1, td), msgs.dtype)
    acc, _ = jax.lax.scan(chunk_step, init, (w_c, v_c))
    return acc[:, : bg.block_size].reshape((k, bg.block_size) + tail)


def balanced_push(
    bg: BlockedGraph,
    values: jnp.ndarray,
    reduce: str = "sum",
    combine: Optional[Callable] = None,
):
    """Sparsity-aware TOCAB push.  Every bin accumulates into its blocks'
    dense destination windows; windows are disjoint and contiguous so the
    global result is a reshape + slice (no cross-bin conflicts)."""
    assert bg.direction == "push"
    sched = require_schedule(bg)
    _record_bins(bg, "push", "balanced_push")
    tail = values.shape[1:]
    full = jnp.full(
        (bg.num_blocks, bg.block_size) + tail,
        REDUCE_IDENTITY[reduce], values.dtype)
    for bin_id in range(len(BIN_NAMES)):
        ids = sched.blocks_in(bin_id)
        if not ids:
            continue
        widx, mask, msgs = _push_msgs(bg, ids, values, reduce, combine)
        if bin_id == BIN_DENSE and _dense_eligible(reduce, combine):
            slab = _push_window_onehot(bg, widx, mask, msgs)
        elif bin_id == BIN_MEDIUM or bin_id == BIN_DENSE:
            slab = _push_window_chunked(bg, widx, mask, msgs, reduce)
        else:
            slab = _push_window_sparse(bg, widx, mask, msgs, reduce)
        full = full.at[jnp.asarray(ids, jnp.int32)].set(slab.astype(full.dtype))
    return full.reshape((bg.num_blocks * bg.block_size,) + tail)[: bg.n]


# ====================================================================== #
# Edge-value reduce (GNN primitive) through the same bins
# ====================================================================== #
def balanced_edge_reduce(
    bg: BlockedGraph,
    flat_edge_vals: jnp.ndarray,
    reduce: str = "sum",
):
    """Sparsity-aware twin of :func:`repro.core.tocab.tocab_edge_reduce`:
    per-edge values (original order) reduced to the compacted side, with
    each bin on its matched strategy.  Dense bins use the one-hot tile path
    (messages carry no separable ``values``/``edge_vals`` factorization, so
    the Pallas SpMM kernel does not apply)."""
    from .tocab import blocked_edge_values, reduce_partials

    sched = require_schedule(bg)
    _record_bins(bg, bg.direction, "balanced_edge_reduce")
    vals = blocked_edge_values(bg, flat_edge_vals)
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], vals.dtype)
    mask_full = bg.edge_mask
    m = mask_full
    while m.ndim < vals.ndim:
        m = m[..., None]
    vals = jnp.where(m, vals, ident)
    tail = vals.shape[2:]
    partials = jnp.full(
        (bg.num_blocks, bg.local_budget) + tail, ident, vals.dtype)
    for bin_id in range(len(BIN_NAMES)):
        ids = sched.blocks_in(bin_id)
        if not ids:
            continue
        # compact_idx is bounded by n_local, so the slab width must come from
        # the compact budget — row_budget_per_bin is the *window* side on
        # push layouts and under-sizes the scatter (cross-block spill).
        rb = _compact_budget(sched, bin_id, bg.local_budget)
        idx = jnp.asarray(ids, jnp.int32)
        cidx = jnp.take(bg.compact_idx, idx, axis=0)
        mask = jnp.take(mask_full, idx, axis=0)
        msgs = jnp.take(vals, idx, axis=0)
        if bin_id == BIN_DENSE and reduce == "sum":
            sub = _reduce_msgs_onehot(rb, cidx, mask, msgs)
        elif bin_id == BIN_SPARSE:
            sub = _reduce_msgs_sparse(rb, cidx, mask, msgs, reduce)
        else:
            sub = _reduce_msgs_scan(rb, cidx, mask, msgs, reduce)
        partials = partials.at[idx, : sub.shape[1]].set(sub.astype(partials.dtype))
    return reduce_partials(bg, partials, reduce)

"""GraphCage core: TOCAB cache-aware graph processing (the paper's contribution).

Public surface:

* :mod:`repro.core.graph` — CSR graph containers + generators
* :mod:`repro.core.partition` — TOCAB static 1D blocking + local-ID compaction
* :mod:`repro.core.tocab` — blocked pull/push engines + reduction phase
* :mod:`repro.core.pagerank` / :mod:`repro.core.spmv` /
  :mod:`repro.core.traversal` — the paper's benchmark algorithms
* :mod:`repro.core.cache_model` — analytic LLC model (Fig. 9/10 repro)
"""
from .graph import (  # noqa: F401
    DeviceGraph,
    Graph,
    from_edges,
    graph_fingerprint,
    grid_graph,
    rmat_graph,
    to_networkx,
    uniform_random_graph,
)
from .partition import BlockedGraph, build_blocked, choose_block_size  # noqa: F401
from .balance import (  # noqa: F401
    BIN_NAMES,
    UNWEIGHTED,
    BlockSchedule,
    balanced_edge_reduce,
    balanced_pull,
    balanced_push,
    make_schedule,
)
from .tocab import (  # noqa: F401
    baseline_pull,
    baseline_push,
    cb_pull,
    reduce_partials,
    segment_reduce,
    tocab_edge_reduce,
    tocab_pull,
    tocab_pull_partials,
    tocab_push,
)
from .pagerank import PR_VARIANTS, pagerank, pagerank_iteration  # noqa: F401
from .spmv import SPMV_VARIANTS, spmv  # noqa: F401
from .traversal import (  # noqa: F401
    INF_DEPTH, bc, bfs, connected_components, sssp,
)
from .cache_model import CacheConfig, CacheSim, simulate_pagerank_variant  # noqa: F401

"""Design-choice ablations from paper §3.1 — the alternatives GraphCage
argues AGAINST, implemented so the argument is measurable:

* **2D blocking** (§3.1 choice 2): partition on BOTH source and destination
  ranges.  More, smaller blocks → fewer reuses captured per block + more
  merge overhead.  ``build_blocked_2d`` + ``tocab_pull_2d``.
* **Dynamic blocking / propagation blocking** (§3.1 choice 3, Beamer's PB):
  no preprocessing — per-iteration runtime binning of (dst, contribution)
  pairs into cache-sized buckets, then bucket-sequential accumulation.
  Costs extra stores+loads for the intermediate buffers every iteration
  (the paper's argument for static blocking).  ``propagation_blocking_pull``.

Both are numerically identical to the flat baseline (tested) and are
benchmarked against TOCAB in ``benchmarks/paper_figs.py::ablation_blocking``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DeviceGraph, Graph
from .partition import REDUCE_IDENTITY

__all__ = ["build_blocked_2d", "tocab_pull_2d", "propagation_blocking_pull",
           "Blocked2D"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Blocked2D:
    """2D-blocked edges: tile (bi, bj) holds edges with src∈range(bi),
    dst∈range(bj).  Stored as a flat (num_tiles, edge_budget) slab grid."""

    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))
    tiles_per_side: int = dataclasses.field(metadata=dict(static=True))
    edge_budget: int = dataclasses.field(metadata=dict(static=True))
    src_rel: jnp.ndarray  # int32[T, eb] src − src_block_lo
    dst_rel: jnp.ndarray  # int32[T, eb] dst − dst_block_lo
    edge_mask: jnp.ndarray  # bool[T, eb]
    edge_vals: Optional[jnp.ndarray] = None


def build_blocked_2d(g: Graph, block_size: int,
                     pad_edges_to: int = 128) -> Blocked2D:
    src, dst = g.edges()
    nb = max(1, -(-g.n // block_size))
    tile = (src // block_size) * nb + (dst // block_size)
    order = np.argsort(tile, kind="stable")
    tile, src, dst = tile[order], src[order], dst[order]
    vals = None if g.vals is None else g.vals[order]
    T = nb * nb
    counts = np.bincount(tile, minlength=T)
    eb = max(pad_edges_to, -(-int(counts.max(initial=1)) // pad_edges_to)
             * pad_edges_to)
    first = np.concatenate([[0], np.cumsum(counts)])[:-1]
    slot = np.arange(len(src)) - np.repeat(first, counts)
    src_rel = np.zeros((T, eb), np.int32)
    dst_rel = np.zeros((T, eb), np.int32)
    mask = np.zeros((T, eb), bool)
    ev = None if vals is None else np.zeros((T, eb), np.float32)
    bi = tile // nb
    bj = tile % nb
    src_rel[tile, slot] = (src - bi * block_size).astype(np.int32)
    dst_rel[tile, slot] = (dst - bj * block_size).astype(np.int32)
    mask[tile, slot] = True
    if ev is not None:
        ev[tile, slot] = vals
    return Blocked2D(
        n=g.n, m=g.m, block_size=block_size, tiles_per_side=nb,
        edge_budget=eb, src_rel=jnp.asarray(src_rel),
        dst_rel=jnp.asarray(dst_rel), edge_mask=jnp.asarray(mask),
        edge_vals=None if ev is None else jnp.asarray(ev))


@partial(jax.jit, static_argnames=("reduce",))
def tocab_pull_2d(bg: Blocked2D, values: jnp.ndarray, reduce: str = "sum"):
    """2D-blocked pull: per tile, gather from the source window and reduce
    into a dense per-tile destination slab; merge tiles per dst block."""
    nb, B = bg.tiles_per_side, bg.block_size
    bi = (jnp.arange(nb * nb, dtype=jnp.int32) // nb)[:, None]
    src_global = bg.src_rel + bi * B
    msgs = jnp.take(values, src_global, axis=0, mode="fill", fill_value=0)
    if bg.edge_vals is not None:
        msgs = msgs * bg.edge_vals
    ident = jnp.asarray(REDUCE_IDENTITY[reduce], msgs.dtype)
    msgs = jnp.where(bg.edge_mask, msgs, ident)
    # per-tile dense partials over the destination window
    flat_idx = (bg.dst_rel
                + jnp.arange(nb * nb, dtype=jnp.int32)[:, None] * B)
    from .tocab import segment_reduce
    partials = segment_reduce(msgs.reshape(-1), flat_idx.reshape(-1),
                              nb * nb * B, reduce)
    # merge: tiles (bi, bj) reduce over bi into dst block bj
    partials = partials.reshape(nb, nb, B)
    if reduce == "sum":
        out = partials.sum(axis=0)
    elif reduce == "min":
        out = partials.min(axis=0)
    else:
        out = partials.max(axis=0)
    return out.reshape(nb * B)[: bg.n]


@partial(jax.jit, static_argnames=("num_bins", "reduce"))
def propagation_blocking_pull(dg: DeviceGraph, values: jnp.ndarray,
                              num_bins: int = 16, reduce: str = "sum"):
    """Dynamic blocking (Beamer's propagation blocking, §3.1/§5):

    Phase 1 (binning): compute per-edge (dst, contribution) pairs and sort
    them by destination *bin* at runtime — this materializes the full
    intermediate stream (the extra loads/stores the paper charges against
    dynamic schemes; visible in the cost analysis + wallclock).
    Phase 2 (accumulate): bucket-sequential segment reduce."""
    msgs = jnp.take(values, dg.src, axis=0, mode="fill", fill_value=0)
    if dg.vals is not None:
        msgs = msgs * dg.vals
    bin_size = -(-dg.n // num_bins)
    order = jnp.argsort(dg.dst // bin_size)  # runtime binning pass
    binned_dst = dg.dst[order]  # intermediate buffer #1
    binned_msgs = msgs[order]  # intermediate buffer #2
    from .tocab import segment_reduce
    return segment_reduce(binned_msgs, binned_dst, dg.n, reduce)

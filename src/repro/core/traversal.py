"""Traversal-based algorithms: BFS, Betweenness Centrality, SSSP (paper §3.3).

Partial-active algorithms keep a changing frontier.  Per the paper, the
GPU/TPU-friendly representation is the **status array** (topology-driven):
dynamic frontier queues are not expressible with static shapes anyway, and the
paper argues status arrays let the per-subgraph ``next`` frontier ride the
same partial-slab + reduction machinery as ``partial_sums``.

Direction optimization (Beamer): iterations with a sparse frontier run in
**push**; dense-frontier iterations run in **pull** — and only the pull
iterations go through TOCAB (the working set only exceeds fast memory when
the frontier is large).  The hybrid switch uses the classic α heuristic on
the frontier's out-edge count.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.obs.metrics import registry as _obs
from .graph import DeviceGraph
from .partition import BlockedGraph
from . import tocab

__all__ = ["bfs", "bc", "sssp", "connected_components", "INF_DEPTH",
           "DEFAULT_ALPHA"]

INF_DEPTH = jnp.iinfo(jnp.int32).max // 2

#: the paper's Beamer direction-switch threshold (m_frontier > m/α → pull)
DEFAULT_ALPHA = 15.0


def _resolve_traversal(obj, schedule: str, alpha, workload: str,
                       impl: str = "slab"):
    """Concretize ``schedule="auto"`` / ``impl="auto"`` / ``alpha=None``
    from the tuning DB.

    Runs outside jit (the public wrappers call it before dispatching to the
    jitted bodies) so the jit cache is keyed on the concrete values and a
    re-tune takes effect on the next call."""
    want_auto = schedule == "auto"
    rs = tocab.resolve_schedule(obj, schedule, workload=workload)
    ri = tocab.resolve_impl(obj, impl, workload=workload)
    rs, ri = tocab._reconcile_fused(rs, ri, schedule, impl)
    if alpha is None:
        if want_auto:
            from repro.tune.plan import resolve_alpha

            alpha = resolve_alpha(obj, workload=workload)
        else:
            alpha = DEFAULT_ALPHA
    return rs, float(alpha), ri


def _callbacks_enabled() -> bool:
    """Per-iteration telemetry uses ``jax.debug.callback`` (a host call per
    loop iteration).  On by default — the CPU-scale graphs don't notice —
    and trace-time gated off with REPRO_OBS_DEVICE_CALLBACKS=0 for
    device-bound runs."""
    return os.environ.get("REPRO_OBS_DEVICE_CALLBACKS", "1") != "0"


def _record_frontier(algo, frontier_size, frontier_edges, use_pull):
    direction = "pull" if bool(use_pull) else "push"
    _obs.histogram(
        "traversal.frontier_size", "active vertices per iteration"
    ).observe(float(frontier_size), algo=algo)
    _obs.histogram(
        "traversal.frontier_edges", "frontier out-edge volume (Beamer m_f)"
    ).observe(float(frontier_edges), algo=algo)
    _obs.counter(
        "traversal.iterations", "iterations by Beamer direction decision"
    ).inc(algo=algo, direction=direction)


def _record_iteration(algo):
    _obs.counter("traversal.iterations", "").inc(algo=algo, direction="pull")


def _emit_frontier(algo: str, frontier, m_frontier, use_pull):
    """Trace-time-gated per-iteration telemetry (runtime values arrive on
    the host via debug.callback)."""
    if _callbacks_enabled():
        jax.debug.callback(partial(_record_frontier, algo),
                           frontier.sum(), m_frontier, use_pull)


def _frontier_reach(
    dg: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    frontier_f32: jnp.ndarray,
    use_pull: jnp.ndarray,
    schedule: str = "uniform",
    impl: str = "slab",
):
    """reached[dst] = max over in-edges of frontier[src]  (0/1 floats).

    ``use_pull`` selects TOCAB pull (dense phase) vs flat push (sparse
    phase).  Both are lowered; `lax.cond` picks at runtime — on TPU the
    pull branch is the blocked kernel, the push branch the flat one.
    ``schedule``/``impl`` must already be concrete (no ``"auto"`` here —
    the public wrappers resolve them before tracing)."""

    def pull_branch(f):
        if bg_pull is None:
            return tocab.baseline_pull(dg, f, reduce="max")
        return tocab.tocab_pull(bg_pull, f, reduce="max", schedule=schedule,
                                impl=impl)

    def push_branch(f):
        return tocab.baseline_push(dg, f, reduce="max")

    return jax.lax.cond(use_pull, pull_branch, push_branch, frontier_f32)


def bfs(
    dg: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    source: jnp.ndarray,
    max_iters: int = 0,
    alpha: Optional[float] = None,
    schedule: str = "uniform",
    impl: str = "slab",
):
    """Direction-optimizing BFS.  ``dg``/``bg_pull`` are over Gᵀ edges
    oriented (src→dst) = (in-neighbour → vertex), i.e. the pull layout.

    ``schedule="auto"`` / ``impl="auto"`` consult the tuning DB for the
    pull phase; ``alpha=None`` takes the tuned Beamer α under ``"auto"``
    and the paper's 15 otherwise.

    Returns (depth int32[n], levels int32, push_iters, pull_iters)."""
    schedule, alpha, impl = _resolve_traversal(
        bg_pull if bg_pull is not None else dg, schedule, alpha, "bfs", impl)
    return _bfs_jit(dg, bg_pull, source, max_iters, alpha, schedule, impl)


@partial(jax.jit, static_argnames=("max_iters", "alpha", "schedule", "impl"))
def _bfs_jit(
    dg: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    source: jnp.ndarray,
    max_iters: int,
    alpha: float,
    schedule: str,
    impl: str = "slab",
):
    n = dg.n
    max_iters = max_iters or n
    depth0 = jnp.full((n,), INF_DEPTH, jnp.int32).at[source].set(0)
    frontier0 = jnp.zeros((n,), jnp.float32).at[source].set(1.0)

    def cond(state):
        _, frontier, level, pp = state
        return (frontier.sum() > 0) & (level < max_iters)

    def body(state):
        depth, frontier, level, (n_push, n_pull) = state
        # Beamer heuristic: frontier out-edge volume vs m/alpha.
        m_frontier = (frontier * dg.out_degree.astype(jnp.float32)).sum()
        use_pull = m_frontier > (dg.m / alpha)
        _emit_frontier("bfs", frontier, m_frontier, use_pull)
        reached = _frontier_reach(dg, bg_pull, frontier, use_pull, schedule,
                                  impl)
        new_frontier = (reached > 0) & (depth >= INF_DEPTH)
        depth = jnp.where(new_frontier, level + 1, depth)
        counts = (
            n_push + jnp.where(use_pull, 0, 1),
            n_pull + jnp.where(use_pull, 1, 0),
        )
        return depth, new_frontier.astype(jnp.float32), level + 1, counts

    depth, _, levels, (n_push, n_pull) = jax.lax.while_loop(
        cond, body, (depth0, frontier0, jnp.int32(0), (jnp.int32(0), jnp.int32(0)))
    )
    return depth, levels, n_push, n_pull


def bc(
    dg: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    source: jnp.ndarray,
    max_levels: int = 64,
    alpha: Optional[float] = None,
    schedule: str = "uniform",
    impl: str = "slab",
):
    """Brandes betweenness centrality from one source (paper Alg. 3 + the
    standard dependency back-propagation).  Forward phase = BFS computing
    depth δ and shortest-path counts σ; backward phase accumulates
    dependencies level by level.  ``schedule`` / ``alpha`` / ``impl`` as in
    :func:`bfs`.

    Returns (bc_scores f32[n], depth, sigma)."""
    schedule, alpha, impl = _resolve_traversal(
        bg_pull if bg_pull is not None else dg, schedule, alpha, "bfs", impl)
    return _bc_jit(dg, bg_pull, source, max_levels, alpha, schedule, impl)


@partial(jax.jit, static_argnames=("max_levels", "alpha", "schedule",
                                   "impl"))
def _bc_jit(
    dg: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    source: jnp.ndarray,
    max_levels: int,
    alpha: float,
    schedule: str,
    impl: str = "slab",
):
    n = dg.n
    depth0 = jnp.full((n,), INF_DEPTH, jnp.int32).at[source].set(0)
    sigma0 = jnp.zeros((n,), jnp.float32).at[source].set(1.0)
    frontier0 = jnp.zeros((n,), jnp.float32).at[source].set(1.0)

    # ---------------- forward: depth + sigma ---------------- #
    def fwd_cond(state):
        _, _, frontier, level = state
        return (frontier.sum() > 0) & (level < max_levels)

    def fwd_body(state):
        depth, sigma, frontier, level = state
        m_frontier = (frontier * dg.out_degree.astype(jnp.float32)).sum()
        use_pull = m_frontier > (dg.m / alpha)
        _emit_frontier("bc", frontier, m_frontier, use_pull)
        reached = _frontier_reach(dg, bg_pull, frontier, use_pull, schedule,
                                  impl)
        new_frontier = (reached > 0) & (depth >= INF_DEPTH)
        depth = jnp.where(new_frontier, level + 1, depth)
        # σ[dst] += Σ σ[src] over tree edges (src on frontier level).
        path_msgs = jnp.where(frontier > 0, sigma, 0.0)
        sig_in = (
            tocab.tocab_pull(bg_pull, path_msgs, reduce="sum",
                             schedule=schedule, impl=impl)
            if bg_pull is not None
            else tocab.baseline_pull(dg, path_msgs, reduce="sum")
        )
        sigma = jnp.where(new_frontier, sig_in, sigma)
        return depth, sigma, new_frontier.astype(jnp.float32), level + 1

    depth, sigma, _, levels = jax.lax.while_loop(
        fwd_cond, fwd_body, (depth0, sigma0, frontier0, jnp.int32(0))
    )

    # ---------------- backward: dependency accumulation ---------------- #
    # δ(v) = Σ_{w: (v,w) tree edge} σ(v)/σ(w) · (1 + δ(w)); iterate levels
    # from deepest-1 down to 0.  Pull over G (v gathers from out-neighbours
    # w) — which is a pull over Gᵀ's reversed edges = push layout of dg;
    # we simply reuse dg with roles flipped (dst→src).
    safe_sigma = jnp.maximum(sigma, 1e-30)

    def bwd_body(i, delta):
        level = levels - 1 - i  # deepest-1 ... 0
        coef = jnp.where(depth < INF_DEPTH, (1.0 + delta) / safe_sigma, 0.0)
        # message flows w → v along edge (v,w): gather at the *src* side of
        # each edge from its dst side (push layout; flat per the paper —
        # backward frontiers are level-sparse).
        msgs = coef[dg.dst] * jnp.where(depth[dg.dst] == level + 1, 1.0, 0.0)
        acc = tocab.segment_reduce(msgs, dg.src, n, "sum")
        contrib = sigma * acc
        delta = jnp.where(depth == level, delta + contrib, delta)
        return delta

    delta = jax.lax.fori_loop(0, levels, bwd_body, jnp.zeros((n,), jnp.float32))
    bc_scores = jnp.where(depth < INF_DEPTH, delta, 0.0).at[source].set(0.0)
    return bc_scores, depth, sigma


def sssp(
    dg: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    source: jnp.ndarray,
    max_iters: int = 0,
    schedule: str = "uniform",
    impl: str = "slab",
):
    """Bellman-Ford SSSP (min-plus semiring), TOCAB pull per iteration.

    ``dg`` must carry edge weights.  Returns (dist f32[n], iters)."""
    schedule, _, impl = _resolve_traversal(
        bg_pull if bg_pull is not None else dg, schedule, DEFAULT_ALPHA,
        "bfs", impl)
    return _sssp_jit(dg, bg_pull, source, max_iters, schedule, impl)


@partial(jax.jit, static_argnames=("max_iters", "schedule", "impl"))
def _sssp_jit(
    dg: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    source: jnp.ndarray,
    max_iters: int,
    schedule: str,
    impl: str = "slab",
):
    n = dg.n
    max_iters = max_iters or n
    inf = jnp.float32(jnp.inf)
    dist0 = jnp.full((n,), inf).at[source].set(0.0)
    plus = lambda d, w: d + (w if w is not None else 1.0)

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        dist, _, it = state
        if _callbacks_enabled():
            jax.debug.callback(partial(_record_iteration, "sssp"))
        relaxed = (
            tocab.tocab_pull(bg_pull, dist, reduce="min", combine=plus,
                             schedule=schedule, impl=impl)
            if bg_pull is not None
            else tocab.baseline_pull(dg, dist, reduce="min", combine=plus)
        )
        new_dist = jnp.minimum(dist, relaxed)
        return new_dist, jnp.any(new_dist < dist), it + 1

    dist, _, iters = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True), 0))
    return dist, iters


def connected_components(
    dg: DeviceGraph,
    dg_t: DeviceGraph,
    bg_pull: Optional[BlockedGraph] = None,
    max_iters: int = 0,
    schedule: str = "uniform",
    impl: str = "slab",
):
    """Weakly-connected components via min-label propagation (all-active,
    min semiring — the same blocked pull engine as SSSP).

    ``dg_t`` is the transpose edge set (labels must flow both directions
    for *weak* connectivity).  Returns (labels int32[n], iters)."""
    schedule, _, impl = _resolve_traversal(
        bg_pull if bg_pull is not None else dg, schedule, DEFAULT_ALPHA,
        "bfs", impl)
    return _cc_jit(dg, dg_t, bg_pull, max_iters, schedule, impl)


@partial(jax.jit, static_argnames=("max_iters", "schedule", "impl"))
def _cc_jit(
    dg: DeviceGraph,
    dg_t: DeviceGraph,
    bg_pull: Optional[BlockedGraph],
    max_iters: int,
    schedule: str,
    impl: str = "slab",
):
    n = dg.n
    max_iters = max_iters or n
    labels0 = jnp.arange(n, dtype=jnp.float32)
    ignore = lambda m, w: m  # unweighted

    def relax(labels):
        fwd = (
            tocab.tocab_pull(bg_pull, labels, reduce="min", combine=ignore,
                             schedule=schedule, impl=impl)
            if bg_pull is not None
            else tocab.baseline_pull(dg, labels, reduce="min", combine=ignore)
        )
        bwd = tocab.baseline_pull(dg_t, labels, reduce="min", combine=ignore)
        return jnp.minimum(labels, jnp.minimum(fwd, bwd))

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        if _callbacks_enabled():
            jax.debug.callback(partial(_record_iteration, "cc"))
        new = relax(labels)
        return new, jnp.any(new < labels), it + 1

    labels, _, iters = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), 0))
    return labels.astype(jnp.int32), iters

"""PageRank in all of the paper's configurations (Fig. 6).

Variants (names follow the paper's evaluation bars):

* ``base``      — flat pull, no optimization (Alg. 1)
* ``push``      — flat push (Alg. 2; no atomics on TPU → segment reduce)
* ``cb``        — conventional cache blocking (blocked, no compaction)
* ``gc-pull``   — GraphCage TOCAB pull (Alg. 4 + reduction phase)
* ``gc-push``   — GraphCage TOCAB push (Alg. 5)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .balance import UNWEIGHTED as _unweighted
from .graph import DeviceGraph
from .partition import BlockedGraph
from . import tocab

__all__ = ["pagerank", "pagerank_iteration", "PR_VARIANTS"]

PR_VARIANTS = ("base", "push", "cb", "gc-pull", "gc-push")


def _gather_sums(variant: str, dg, bg, contributions, schedule="uniform",
                 impl="slab", epilogue=None, allow_fallback=None):
    # PR is unweighted: the UNWEIGHTED sentinel combine ignores any edge
    # values the graph carries (and keeps the dense tile path eligible).
    kw = dict(reduce="sum", combine=_unweighted)
    if variant == "base":
        return tocab.baseline_pull(dg, contributions, **kw)
    if variant == "push":
        return tocab.baseline_push(dg, contributions, **kw)
    if variant == "cb":
        return tocab.cb_pull(bg, contributions, **kw)
    if variant == "gc-pull":
        return tocab.tocab_pull(bg, contributions, schedule=schedule,
                                impl=impl, epilogue=epilogue,
                                allow_fallback=allow_fallback, **kw)
    if variant == "gc-push":
        return tocab.tocab_push(bg, contributions, schedule=schedule,
                                impl=impl, epilogue=epilogue,
                                allow_fallback=allow_fallback, **kw)
    raise ValueError(f"unknown PR variant {variant!r}")


def pagerank_iteration(
    variant: str,
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    rank: jnp.ndarray,
    out_degree: jnp.ndarray,
    damping: float = 0.85,
    handle_dangling: bool = True,
    schedule: str = "uniform",
    impl: str = "slab",
    allow_fallback=None,
):
    """One PR iteration: contributions → gather/scatter → apply.

    GraphCage variants hand the apply step to the engine as an affine
    epilogue ``sums*damping + add`` — the fused impl folds it into the
    kernel's final block visit, the slab impl applies the identical
    expression as a trailing pass, so both stay bit-identical.  Dangling
    mass is known before the gather (it only reads ``rank``), which is what
    lets the apply collapse into one affine form."""
    n = rank.shape[0]
    safe_deg = jnp.maximum(out_degree, 1).astype(rank.dtype)
    contributions = rank / safe_deg
    contributions = jnp.where(out_degree > 0, contributions, 0.0)
    dangling = jnp.where(out_degree > 0, 0.0, rank).sum() if handle_dangling else 0.0
    if variant in ("gc-pull", "gc-push"):
        add = (1.0 - damping) / n + damping * (dangling / n)
        return _gather_sums(variant, dg, bg, contributions, schedule,
                            impl, epilogue=(damping, add),
                            allow_fallback=allow_fallback)
    sums = _gather_sums(variant, dg, bg, contributions, schedule)
    return (1.0 - damping) / n + damping * (sums + dangling / n)


def pagerank(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph] = None,
    variant: str = "gc-pull",
    damping: float = 0.85,
    tol: float = 1e-6,
    max_iters: int = 200,
    handle_dangling: bool = True,
    schedule: str = "uniform",
    impl: str = "slab",
    allow_fallback=None,
):
    """Iterate PR until the L1 delta falls below ``tol``.

    Returns (rank, iterations).  ``schedule="auto"`` / ``impl="auto"``
    consult the tuning DB (``repro.tune``) via the graph's build-time
    fingerprint; resolution happens here, outside jit, so the jit cache is
    keyed on the concrete choices and a re-tune takes effect on the next
    call.  ``impl="auto"`` (or ``allow_fallback=True``) also arms the
    fused→slab→reference degradation ladder: a kernel-dispatch failure at
    trace time degrades the engine instead of crashing the run, and the
    memoized verdict (``repro.resilience.degrade``) pins later calls for
    this graph straight to the working rung."""
    from repro.resilience import degrade

    obj = bg if bg is not None else dg
    rs = tocab.resolve_schedule(obj, schedule, workload="pagerank")
    ri = tocab.resolve_impl(obj, impl, workload="pagerank")
    rs, ri = tocab._reconcile_fused(rs, ri, schedule, impl)
    allow = degrade.fallback_allowed(impl, allow_fallback)
    if allow and bg is not None and variant in ("gc-pull", "gc-push"):
        site = "tocab_pull" if variant == "gc-pull" else "tocab_push"
        ri = degrade.apply_verdict(bg.fingerprint, site, ri)
    return _pagerank_jit(
        dg, bg, variant, damping, tol, max_iters, handle_dangling, rs, ri,
        allow)


@partial(
    jax.jit,
    static_argnames=(
        "variant", "damping", "tol", "max_iters", "handle_dangling",
        "schedule", "impl", "allow_fallback",
    ),
)
def _pagerank_jit(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    variant: str,
    damping: float,
    tol: float,
    max_iters: int,
    handle_dangling: bool,
    schedule: str,
    impl: str = "slab",
    allow_fallback: bool = False,
):
    n = dg.n
    rank0 = jnp.full((n,), 1.0 / n, jnp.float32)

    def cond(state):
        _, delta, it = state
        return (delta > tol) & (it < max_iters)

    def body(state):
        rank, _, it = state
        new_rank = pagerank_iteration(
            variant, dg, bg, rank, dg.out_degree, damping, handle_dangling,
            schedule, impl, allow_fallback,
        )
        return new_rank, jnp.abs(new_rank - rank).sum(), it + 1

    rank, _, iters = jax.lax.while_loop(cond, body, (rank0, jnp.inf, 0))
    return rank, iters

"""PageRank in all of the paper's configurations (Fig. 6).

Variants (names follow the paper's evaluation bars):

* ``base``      — flat pull, no optimization (Alg. 1)
* ``push``      — flat push (Alg. 2; no atomics on TPU → segment reduce)
* ``cb``        — conventional cache blocking (blocked, no compaction)
* ``gc-pull``   — GraphCage TOCAB pull (Alg. 4 + reduction phase)
* ``gc-push``   — GraphCage TOCAB push (Alg. 5)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .balance import UNWEIGHTED as _unweighted
from .graph import DeviceGraph
from .partition import BlockedGraph
from . import tocab

__all__ = ["pagerank", "pagerank_iteration", "PR_VARIANTS"]

PR_VARIANTS = ("base", "push", "cb", "gc-pull", "gc-push")


def _gather_sums(variant: str, dg, bg, contributions, schedule="uniform"):
    # PR is unweighted: the UNWEIGHTED sentinel combine ignores any edge
    # values the graph carries (and keeps the dense tile path eligible).
    kw = dict(reduce="sum", combine=_unweighted)
    if variant == "base":
        return tocab.baseline_pull(dg, contributions, **kw)
    if variant == "push":
        return tocab.baseline_push(dg, contributions, **kw)
    if variant == "cb":
        return tocab.cb_pull(bg, contributions, **kw)
    if variant == "gc-pull":
        return tocab.tocab_pull(bg, contributions, schedule=schedule, **kw)
    if variant == "gc-push":
        return tocab.tocab_push(bg, contributions, schedule=schedule, **kw)
    raise ValueError(f"unknown PR variant {variant!r}")


def pagerank_iteration(
    variant: str,
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    rank: jnp.ndarray,
    out_degree: jnp.ndarray,
    damping: float = 0.85,
    handle_dangling: bool = True,
    schedule: str = "uniform",
):
    """One PR iteration: contributions → gather/scatter → apply."""
    n = rank.shape[0]
    safe_deg = jnp.maximum(out_degree, 1).astype(rank.dtype)
    contributions = rank / safe_deg
    contributions = jnp.where(out_degree > 0, contributions, 0.0)
    sums = _gather_sums(variant, dg, bg, contributions, schedule)
    dangling = jnp.where(out_degree > 0, 0.0, rank).sum() if handle_dangling else 0.0
    return (1.0 - damping) / n + damping * (sums + dangling / n)


def pagerank(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph] = None,
    variant: str = "gc-pull",
    damping: float = 0.85,
    tol: float = 1e-6,
    max_iters: int = 200,
    handle_dangling: bool = True,
    schedule: str = "uniform",
):
    """Iterate PR until the L1 delta falls below ``tol``.

    Returns (rank, iterations).  ``schedule="auto"`` consults the tuning DB
    (``repro.tune``) via the graph's build-time fingerprint; resolution
    happens here, outside jit, so the jit cache is keyed on the concrete
    schedule and a re-tune takes effect on the next call."""
    schedule = tocab.resolve_schedule(
        bg if bg is not None else dg, schedule, workload="pagerank")
    return _pagerank_jit(
        dg, bg, variant, damping, tol, max_iters, handle_dangling, schedule)


@partial(
    jax.jit,
    static_argnames=(
        "variant", "damping", "tol", "max_iters", "handle_dangling", "schedule",
    ),
)
def _pagerank_jit(
    dg: DeviceGraph,
    bg: Optional[BlockedGraph],
    variant: str,
    damping: float,
    tol: float,
    max_iters: int,
    handle_dangling: bool,
    schedule: str,
):
    n = dg.n
    rank0 = jnp.full((n,), 1.0 / n, jnp.float32)

    def cond(state):
        _, delta, it = state
        return (delta > tol) & (it < max_iters)

    def body(state):
        rank, _, it = state
        new_rank = pagerank_iteration(
            variant, dg, bg, rank, dg.out_degree, damping, handle_dangling,
            schedule,
        )
        return new_rank, jnp.abs(new_rank - rank).sum(), it + 1

    rank, _, iters = jax.lax.while_loop(cond, body, (rank0, jnp.inf, 0))
    return rank, iters

"""Render and diff BENCH_*.json artifacts.

    python -m repro.obs.report BENCH_fig6_pagerank.json
    python -m repro.obs.report BENCH_new.json --baseline BENCH_old.json

The first form prints the run fingerprint and a table of benchmark records;
the second additionally prints per-metric deltas against the baseline run
(positive runtime delta = regression).  Exit code is 0 unless --fail-above
is given and some runtime regressed more than that percentage."""
from __future__ import annotations

import argparse
import sys
from typing import Optional

from .export import read_json

__all__ = ["render", "diff", "render_diff", "main"]

_SKIP_FIELDS = ("name",)


def _numeric_fields(records: list) -> list:
    fields: list = []
    for r in records:
        for k, v in r.items():
            if k not in _SKIP_FIELDS and isinstance(v, (int, float)) \
                    and k not in fields:
                fields.append(k)
    return fields


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return "" if v is None else str(v)


def _table(headers: list, rows: list) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    out = [line(headers), line(["-" * w for w in widths])]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


def render(payload: dict) -> str:
    """Human-readable table for one BENCH payload."""
    fp = payload.get("fingerprint", {})
    head = (
        f"# {payload.get('name', '?')}  [{payload.get('schema', '?')}]\n"
        f"# jax={fp.get('jax_version')} backend={fp.get('backend')} "
        f"devices={fp.get('device_count')} git={fp.get('git_sha')}"
    )
    records = payload.get("records", [])
    if not records:
        return head + "\n(no records)"
    fields = _numeric_fields(records)
    rows = [[r.get("name", "?")] + [_fmt(r.get(f)) for f in fields]
            for r in records]
    return head + "\n" + _table(["name"] + fields, rows)


def diff(new: dict, old: dict) -> list:
    """Per-record, per-metric deltas between two BENCH payloads.

    Returns rows ``{name, metric, old, new, delta, pct}`` for every numeric
    field present in both versions of a same-named record."""
    old_by_name = {r.get("name"): r for r in old.get("records", [])}
    out = []
    for r in new.get("records", []):
        base = old_by_name.get(r.get("name"))
        if base is None:
            continue
        for k, v in r.items():
            if k in _SKIP_FIELDS or not isinstance(v, (int, float)):
                continue
            b = base.get(k)
            if not isinstance(b, (int, float)):
                continue
            delta = v - b
            pct = (delta / b * 100.0) if b else None
            out.append({"name": r["name"], "metric": k, "old": b,
                        "new": v, "delta": delta, "pct": pct})
    return out


def render_diff(rows: list, only_metric: Optional[str] = None) -> str:
    if only_metric:
        rows = [r for r in rows if r["metric"] == only_metric]
    if not rows:
        return "(no overlapping records to diff)"
    table_rows = [
        [r["name"], r["metric"], _fmt(r["old"]), _fmt(r["new"]),
         _fmt(r["delta"]),
         ("" if r["pct"] is None else f"{r['pct']:+.1f}%")]
        for r in rows
    ]
    return _table(["name", "metric", "old", "new", "delta", "pct"],
                  table_rows)


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a BENCH_*.json artifact, optionally diffed "
                    "against a baseline run.")
    ap.add_argument("bench", help="BENCH_*.json to render")
    ap.add_argument("--baseline", default=None,
                    help="prior BENCH_*.json to diff against")
    ap.add_argument("--metric", default=None,
                    help="restrict the diff table to one metric "
                         "(e.g. us_per_call)")
    ap.add_argument("--fail-above", type=float, default=None, metavar="PCT",
                    help="exit 1 if any us_per_call regressed more than PCT%%")
    args = ap.parse_args(argv)

    payload = read_json(args.bench)
    print(render(payload))
    if args.baseline is None:
        return 0
    rows = diff(payload, read_json(args.baseline))
    print(f"\n## delta vs {args.baseline}\n")
    print(render_diff(rows, only_metric=args.metric))
    if args.fail_above is not None:
        bad = [r for r in rows
               if r["metric"] == "us_per_call" and r["pct"] is not None
               and r["pct"] > args.fail_above]
        if bad:
            print(f"\nREGRESSION: {len(bad)} record(s) slower than "
                  f"+{args.fail_above}%", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Nested tracing spans with JSONL emission and device-work attribution.

Usage::

    from repro import obs

    with obs.span("train.step", step=i) as sp:
        out = step_fn(...)
        sp.block(out)          # jax.block_until_ready → device time lands
                               # in THIS span, not a later data-dependent one

    obs.trace.set_sink("trace.jsonl")      # persist events as JSONL
    with obs.trace.profiler("/tmp/prof"):  # opt-in jax.profiler trace
        ...

Span events carry ``name, ts, dur_s, blocked_s, depth, parent, attrs`` and
are buffered in memory (readable via :func:`events`) and appended to the
JSONL sink when one is configured.  Nesting is tracked per-thread."""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Optional

from .metrics import registry

__all__ = [
    "Span", "span", "events", "clear", "set_sink", "profiler",
]

_TLS = threading.local()
_BUF_LOCK = threading.Lock()
_EVENTS: list = []
_SINK_PATH: Optional[str] = None


def _stack() -> list:
    s = getattr(_TLS, "stack", None)
    if s is None:
        s = _TLS.stack = []
    return s


def set_sink(path: Optional[str]):
    """Append finished span events to ``path`` as JSONL (None disables)."""
    global _SINK_PATH
    _SINK_PATH = path


def events() -> list:
    """Copy of the in-memory span event buffer (finish order)."""
    with _BUF_LOCK:
        return list(_EVENTS)


def clear():
    with _BUF_LOCK:
        _EVENTS.clear()


class Span:
    """One timed region.  Created by :func:`span`; also records its duration
    into the ``obs.span_seconds`` histogram labeled by span name."""

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.blocked_s = 0.0
        self._t0 = 0.0
        self.dur_s: Optional[float] = None

    def block(self, value):
        """``jax.block_until_ready(value)``, attributing the wait to this
        span (recorded separately as ``blocked_s``).  Returns ``value``."""
        import jax

        t0 = time.perf_counter()
        value = jax.block_until_ready(value)
        self.blocked_s += time.perf_counter() - t0
        return value

    def set(self, **attrs):
        self.attrs.update(attrs)


@contextlib.contextmanager
def span(name: str, **attrs):
    """Nested span context manager; yields a :class:`Span`."""
    sp = Span(name, attrs)
    stack = _stack()
    parent = stack[-1].name if stack else None
    depth = len(stack)
    stack.append(sp)
    sp._t0 = time.perf_counter()
    ts = time.time()
    try:
        yield sp
    finally:
        sp.dur_s = time.perf_counter() - sp._t0
        stack.pop()
        event = {
            "name": name,
            "ts": ts,
            "dur_s": sp.dur_s,
            "blocked_s": sp.blocked_s,
            "depth": depth,
            "parent": parent,
            "attrs": sp.attrs,
        }
        with _BUF_LOCK:
            _EVENTS.append(event)
            sink = _SINK_PATH
        if sink is not None:
            with open(sink, "a") as f:
                f.write(json.dumps(event, default=str) + "\n")
        registry.histogram(
            "obs.span_seconds", "span wall time by name"
        ).observe(sp.dur_s, name=name)


@contextlib.contextmanager
def profiler(logdir: str):
    """Opt-in ``jax.profiler`` trace around a region (TensorBoard-readable).

    Separate from spans on purpose: the profiler costs real overhead and
    disk, so it is never implied by instrumentation — callers reach for it
    explicitly when a span shows an anomaly worth a device timeline."""
    import jax

    with jax.profiler.trace(logdir):
        yield

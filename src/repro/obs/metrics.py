"""Process-wide metrics registry: counters, gauges, histograms — all with
labeled series (Prometheus-style, zero dependencies).

Design constraints, in order:

1. **Deterministic aggregation** — a snapshot is a plain nested dict with
   series sorted by label; two identical runs produce identical snapshots
   (histograms store exact count/sum/min/max plus fixed log2 buckets).
2. **Safe under jit tracing** — recording takes host Python scalars only;
   the hot paths record *static* facts at trace time (shapes, block counts)
   and route *runtime* values through ``jax.debug.callback``.
3. **Cheap** — one dict lookup + float add per record, single lock (the
   checkpoint writer thread records too).
"""
from __future__ import annotations

import math
import threading
from typing import Optional

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "registry"]


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Metric:
    kind = "metric"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._series: dict = {}

    def _snapshot_value(self, v):
        return v

    def snapshot(self) -> dict:
        with self._lock:
            series = [
                {"labels": dict(k), "value": self._snapshot_value(v)}
                for k, v in sorted(self._series.items())
            ]
        return {"kind": self.kind, "help": self.help, "series": series}


class Counter(_Metric):
    """Monotonic accumulator; ``inc(v, **labels)``."""

    kind = "counter"

    def inc(self, v: float = 1.0, **labels):
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + float(v)

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0.0)


class Gauge(_Metric):
    """Last-write-wins value; ``set(v, **labels)``."""

    kind = "gauge"

    def set(self, v: float, **labels):
        with self._lock:
            self._series[_label_key(labels)] = float(v)

    def value(self, **labels) -> Optional[float]:
        return self._series.get(_label_key(labels))


class Histogram(_Metric):
    """Exact count/sum/min/max plus log2 buckets; ``observe(v, **labels)``.

    Buckets are powers of two over the observed magnitude (le=2^i), which
    keeps aggregation deterministic and merge-friendly without configuring
    per-metric bucket boundaries."""

    kind = "histogram"

    def observe(self, v: float, **labels):
        v = float(v)
        key = _label_key(labels)
        bucket = (
            "0" if v <= 0 else f"2^{max(-64, min(64, math.ceil(math.log2(v))))}"
        )
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = {"count": 0, "sum": 0.0, "min": math.inf,
                     "max": -math.inf, "buckets": {}}
                self._series[key] = s
            s["count"] += 1
            s["sum"] += v
            s["min"] = min(s["min"], v)
            s["max"] = max(s["max"], v)
            s["buckets"][bucket] = s["buckets"].get(bucket, 0) + 1

    def _snapshot_value(self, s: dict) -> dict:
        out = dict(s)
        out["mean"] = s["sum"] / s["count"] if s["count"] else 0.0
        out["buckets"] = dict(sorted(s["buckets"].items()))
        return out

    def stats(self, **labels) -> Optional[dict]:
        s = self._series.get(_label_key(labels))
        return None if s is None else self._snapshot_value(s)


class Registry:
    """Named metric store.  ``counter``/``gauge``/``histogram`` are
    get-or-create (idempotent, kind-checked)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}

    def _get(self, cls, name: str, help: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, self._lock)
                self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Deterministic nested-dict view of every metric (JSON-ready)."""
        with self._lock:
            metrics = list(self._metrics.items())
        return {name: m.snapshot() for name, m in sorted(metrics)}

    def reset(self):
        with self._lock:
            self._metrics.clear()


#: the process-wide default registry every instrumented module records into
registry = Registry()

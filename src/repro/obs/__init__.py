"""Observability layer: process-wide metrics registry, nested tracing spans,
and machine-readable exporters.

The paper makes its whole argument through counters (Fig. 9 L2 miss rate,
Fig. 10 DRAM transactions/edge); this package makes the repo's equivalents —
plus runtime telemetry for every hot path (TOCAB engines, traversal,
training, serving) — first-class and uniformly exportable:

* :mod:`repro.obs.metrics` — labeled counters / gauges / histograms in one
  process-wide :data:`~repro.obs.metrics.registry`.
* :mod:`repro.obs.trace`  — nested span context managers emitting JSONL,
  with ``jax.block_until_ready`` attribution and an opt-in
  ``jax.profiler`` hook.
* :mod:`repro.obs.export` — run fingerprint (jax version, backend, device
  count, git SHA) and schema-versioned BENCH JSON writers.
* :mod:`repro.obs.report` — ``python -m repro.obs.report BENCH_x.json
  [--baseline prior.json]`` renders tables and per-metric regression deltas.
"""
from . import export, metrics, trace  # noqa: F401
from .metrics import registry  # noqa: F401
from .trace import span  # noqa: F401

"""Exporters: run-metadata fingerprint and schema-versioned JSON/JSONL.

Every benchmark artifact starts with a fingerprint (jax version, backend,
device count, git SHA) so a regression diff can tell "the code got slower"
apart from "the environment changed"."""
from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
from typing import Iterable, Optional

__all__ = [
    "BENCH_SCHEMA",
    "git_sha",
    "run_fingerprint",
    "versioned_payload",
    "bench_payload",
    "write_json",
    "write_jsonl",
    "read_json",
]

#: bump on any incompatible change to the BENCH_*.json layout
BENCH_SCHEMA = "repro.obs.bench/v1"


def git_sha(root: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=root or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None
    except Exception:
        return None


def run_fingerprint() -> dict:
    """Environment identity for artifact provenance."""
    import jax

    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind if jax.devices() else None,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "git_sha": git_sha(),
    }


def versioned_payload(schema: str, name: str, **sections) -> dict:
    """Skeleton of every schema-versioned artifact this repo writes
    (``repro.obs.bench/v1`` benchmarks, ``repro.tune.db/v1`` tuning DB):
    schema tag + name + environment fingerprint, then the caller's sections
    (``None``-valued sections are dropped)."""
    payload = {"schema": schema, "name": name,
               "fingerprint": run_fingerprint()}
    payload.update((k, v) for k, v in sections.items() if v is not None)
    return payload


def bench_payload(name: str, records: Iterable[dict],
                  metrics: Optional[dict] = None,
                  spans: Optional[list] = None) -> dict:
    """Schema-versioned benchmark artifact.

    ``records`` — the per-measurement rows (name + numeric fields);
    ``metrics`` — a registry snapshot; ``spans`` — trace events."""
    return versioned_payload(BENCH_SCHEMA, name, records=list(records),
                             metrics=metrics, spans=spans)


def write_json(path: str, payload: dict) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, default=str, sort_keys=False)
        f.write("\n")
    os.replace(tmp, path)  # atomic: readers never see a torn artifact
    return path


def write_jsonl(path: str, rows: Iterable[dict]) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r, default=str) + "\n")
    return path


def read_json(path: str) -> dict:
    with open(path) as f:
        return json.load(f)

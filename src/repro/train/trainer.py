"""Training loop: jitted step factory, microbatch gradient accumulation,
checkpoint/restart, straggler watchdog.

``make_train_step`` builds the pjit-compiled step used by both the real
trainer and the multi-pod dry-run: (params, opt_state, batch) → (params,
opt_state, metrics).  Gradient accumulation scans over a leading microbatch
axis — the reduction of microbatch *i* overlaps the forward of *i+1* under
XLA's latency-hiding scheduler (compute/comm overlap knob).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import use_mesh_rules
from repro.obs import trace as obs_trace
from repro.obs.metrics import registry as _obs
from . import checkpoint as ckpt_lib
from .optim import Transform, apply_updates, global_norm

__all__ = ["make_train_step", "Trainer", "StragglerWatchdog"]


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    optimizer: Transform,
    grad_accum: int = 1,
    compress_grads: bool = False,
):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_accum > 1`` expects batch leaves shaped (grad_accum, ...) and
    accumulates gradients across microbatches inside one jitted step.
    ``compress_grads`` casts the cross-replica gradient to bf16 before the
    (implicit) reduction — the error-feedback variant lives in
    repro.dist.collectives for the shard_map path."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc,), (loss, metrics)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc,), (losses, metricses) = jax.lax.scan(micro, (zeros,), batch)
            grads = jax.tree.map(lambda g: g / grad_accum, gacc)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        if compress_grads:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = global_norm(grads)
        return params, opt_state, metrics

    return step


class StragglerWatchdog:
    """Tracks per-step walltime EWMA/variance; flags outliers.

    On a real cluster the flag feeds the scheduler (re-replicate the slow
    host's shard / trigger elastic re-mesh); here it records and reports."""

    def __init__(self, threshold_sigma: float = 3.0, warmup: int = 5):
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self.threshold = threshold_sigma
        self.warmup = warmup
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            # prime the EWMA
            self.mean = dt if self.count == 1 else 0.7 * self.mean + 0.3 * dt
            return False
        sigma = max(self.var, 1e-12) ** 0.5
        # floor: never flag < 1.5× the mean (variance needs priming)
        is_straggler = dt > max(self.mean + self.threshold * sigma,
                                1.5 * self.mean)
        if is_straggler:
            self.flagged.append((step, dt))
            _obs.counter(
                "train.straggler_events", "steps flagged as stragglers"
            ).inc()
            _obs.gauge("train.straggler_last_dt_s", "").set(dt)
        a = 0.05
        delta = dt - self.mean
        self.mean += a * delta
        self.var = (1 - a) * (self.var + a * delta * delta)
        return is_straggler


def _batch_tokens(batch) -> int:
    """Token count of one batch for the throughput gauge: the largest
    integer-typed leaf's element count (labels/ids), 0 if none."""
    best = 0
    for leaf in jax.tree.leaves(batch):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.integer):
            best = max(best, int(leaf.size))
    return best


@dataclasses.dataclass
class Trainer:
    """Checkpoint-resumable training loop (restart-safe by construction:
    state = (params, opt_state, step) is fully captured per checkpoint)."""

    loss_fn: Callable
    optimizer: Transform
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    grad_accum: int = 1
    mesh: Any = None
    donate: bool = True

    def __post_init__(self):
        self._step_fn = make_train_step(self.loss_fn, self.optimizer,
                                        self.grad_accum)
        kwargs = {"donate_argnums": (0, 1)} if self.donate else {}
        self._jitted = jax.jit(self._step_fn, **kwargs)
        self._manager = (
            ckpt_lib.CheckpointManager(self.ckpt_dir, keep=self.keep)
            if self.ckpt_dir else None)
        self.watchdog = StragglerWatchdog()

    def init_state(self, params):
        return params, self.optimizer.init(params)

    def maybe_restore(self, params, opt_state):
        """Resume from the latest checkpoint if one exists."""
        if self._manager is None or ckpt_lib.latest_step(self.ckpt_dir) is None:
            return params, opt_state, 0
        (params, opt_state), step, _ = self._manager.restore((params, opt_state))
        return params, opt_state, step

    def _save(self, step, state):
        t0 = time.perf_counter()
        with obs_trace.span("train.checkpoint", step=step):
            self._manager.save(step, state)
        _obs.histogram("train.checkpoint_seconds",
                       "blocking checkpoint-save duration").observe(
            time.perf_counter() - t0)
        _obs.counter("train.checkpoints", "checkpoint saves issued").inc()

    def run(self, params, opt_state, batches, start_step: int = 0,
            num_steps: int = 100, log_every: int = 10, log_fn=print):
        history = []
        step_hist = _obs.histogram("train.step_seconds",
                                   "per-step walltime (post block_until_ready)")
        step_ctr = _obs.counter("train.steps", "optimizer steps taken")
        with use_mesh_rules(self.mesh):
            for step in range(start_step, num_steps):
                batch = next(batches)
                t0 = time.perf_counter()
                params, opt_state, metrics = self._jitted(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                step_hist.observe(dt)
                step_ctr.inc()
                tokens = _batch_tokens(batch)
                if tokens:
                    _obs.gauge("train.tokens_per_s",
                               "training throughput").set(tokens / max(dt, 1e-9))
                straggler = self.watchdog.observe(step, dt)
                if step % log_every == 0 or step == num_steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    history.append({"step": step, "dt": dt, **m})
                    log_fn(f"step {step:5d} loss={m['loss']:.4f} "
                           f"gnorm={m['grad_norm']:.3f} dt={dt*1e3:.1f}ms"
                           + (" [STRAGGLER]" if straggler else ""))
                if (self._manager is not None and step > start_step
                        and step % self.ckpt_every == 0):
                    self._save(step, (params, opt_state))
        if self._manager is not None:
            self._save(num_steps, (params, opt_state))
            self._manager.wait()
        return params, opt_state, history

"""Optimizers from scratch (optax is not installed in this container).

Minimal gradient-transform algebra: a ``Transform`` has ``init(params)`` and
``update(grads, state, params)``; ``chain`` composes.  Provided: AdamW, SGD
(+momentum), Adafactor (factored second moment — the memory-efficient choice
for 100B-param meshes), global-norm clipping, LR schedules.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Transform", "chain", "scale", "scale_by_schedule", "clip_by_global_norm",
    "adam_moments", "add_decayed_weights", "adamw", "sgd", "adafactor",
    "cosine_schedule", "linear_warmup", "constant_schedule", "apply_updates",
    "global_norm",
]


class Transform(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def chain(*ts: Transform) -> Transform:
    def init(params):
        return tuple(t.init(params) for t in ts)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(ts, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Transform(init, update)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(max_norm: float) -> Transform:
    def init(params):
        return ()

    def update(grads, state, params):
        norm = global_norm(grads)
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree.map(lambda g: g * factor, grads), state

    return Transform(init, update)


def scale(factor: float) -> Transform:
    return Transform(
        lambda p: (),
        lambda g, s, p: (jax.tree.map(lambda x: x * factor, g), s),
    )


def scale_by_schedule(schedule: Callable) -> Transform:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params):
        lr = schedule(count)
        return jax.tree.map(lambda g: g * -lr, grads), count + 1

    return Transform(init, update)


def adam_moments(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Transform:
    def init(params):
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"mu": zeros(), "nu": zeros(), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu)
        return upd, {"mu": mu, "nu": nu, "count": c}

    return Transform(init, update)


def add_decayed_weights(weight_decay: float) -> Transform:
    def update(grads, state, params):
        if weight_decay == 0.0 or params is None:
            return grads, state
        return jax.tree.map(
            lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
        ), state

    return Transform(lambda p: (), update)


def adamw(schedule, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          max_grad_norm: Optional[float] = 1.0) -> Transform:
    parts = []
    if max_grad_norm:
        parts.append(clip_by_global_norm(max_grad_norm))
    parts += [adam_moments(b1, b2, eps), add_decayed_weights(weight_decay),
              scale_by_schedule(schedule)]
    return chain(*parts)


def sgd(schedule, momentum: float = 0.9) -> Transform:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, vel, params):
        vel = jax.tree.map(lambda v, g: momentum * v + g.astype(jnp.float32),
                           vel, grads)
        return vel, vel

    return chain(Transform(init, update), scale_by_schedule(schedule))


def adafactor(schedule, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8) -> Transform:
    """Factored second moment: O(rows+cols) optimizer memory for matrices —
    the memory-efficient choice at 10¹¹-param scale."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"m": jax.tree.map(per, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** -decay

        def per(g, s):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), eps)
                precond = (vr / denom)[..., None] * vc[..., None, :]
                upd = g32 / jnp.sqrt(jnp.maximum(precond, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g32 / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            return upd, new_s

        flat_u, flat_s = [], []
        leaves_g, tdef = jax.tree.flatten(grads)
        leaves_s = tdef.flatten_up_to(state["m"])
        for g, s in zip(leaves_g, leaves_s):
            u, ns = per(g, s)
            flat_u.append(u)
            flat_s.append(ns)
        return (
            jax.tree.unflatten(tdef, flat_u),
            {"m": jax.tree.unflatten(tdef, flat_s), "count": c},
        )

    return chain(Transform(init, update), scale_by_schedule(schedule))


# ---------------------------- schedules ---------------------------- #
def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def linear_warmup(peak_lr: float, warmup_steps: int) -> Callable:
    return lambda step: peak_lr * jnp.minimum(
        1.0, step.astype(jnp.float32) / max(warmup_steps, 1))


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)

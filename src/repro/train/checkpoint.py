"""Sharded checkpointing: atomic, async, keep-k, restore-with-resharding.

Layout per step::

    <dir>/step_000042/
        manifest.json      # treedef, shapes, dtypes, step, mesh shape
        arrays.npz         # flattened leaves (process-local; single-host here)
    <dir>/LATEST           # atomic pointer file

Fault-tolerance contract: writes go to ``step_X.tmp`` then ``os.rename`` —
a crash mid-write never corrupts the LATEST checkpoint.  Restore accepts a
different mesh (elastic): leaves are re-placed with the target shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

# numpy can't round-trip ml_dtypes through savez; store raw views + dtype
_EXOTIC = {}
try:
    import ml_dtypes
    _EXOTIC = {
        "bfloat16": ml_dtypes.bfloat16,
        "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
        "float8_e5m2": ml_dtypes.float8_e5m2,
    }
except ImportError:  # pragma: no cover
    pass


def _to_storable(a: np.ndarray) -> np.ndarray:
    if str(a.dtype) in _EXOTIC:
        return a.view(np.uint8).reshape(a.shape + (a.dtype.itemsize,))
    return a


def _from_storable(a: np.ndarray, dtype_str: str, shape) -> np.ndarray:
    if dtype_str in _EXOTIC:
        return a.reshape(-1).view(_EXOTIC[dtype_str]).reshape(shape)
    return a


def _paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": _to_storable(a)
                for i, a in enumerate(host_leaves)})
    manifest = {
        "step": step,
        "paths": _paths(tree),
        "shapes": [list(a.shape) for a in host_leaves],
        "dtypes": [str(a.dtype) for a in host_leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``target``.  ``shardings`` (same
    structure or a single sharding) enables elastic re-mesh on load."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    host_leaves = [
        _from_storable(data[f"leaf_{i}"], manifest["dtypes"][i],
                       tuple(manifest["shapes"][i]))
        for i in range(len(manifest["paths"]))
    ]
    _, treedef = jax.tree.flatten(target)
    if treedef.num_leaves != len(host_leaves):
        raise ValueError(
            f"checkpoint has {len(host_leaves)} leaves, target expects "
            f"{treedef.num_leaves}")
    if shardings is not None:
        is_sh = lambda x: isinstance(x, jax.sharding.Sharding)
        shard_leaves = jax.tree.leaves(shardings, is_leaf=is_sh)
        if len(shard_leaves) == 1:
            shard_leaves = shard_leaves * len(host_leaves)
        leaves = [jax.device_put(a, s) for a, s in zip(host_leaves, shard_leaves)]
    else:
        leaves = [jax.device_put(a) for a in host_leaves]
    return jax.tree.unflatten(treedef, leaves), step, manifest["extra"]


class CheckpointManager:
    """Async writer with keep-k GC and crash-safe commits."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_write: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _do_save(self, step, host_tree, extra):
        try:
            save(self.dir, step, host_tree, extra)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # device_get happens synchronously (consistent snapshot); the disk
        # write overlaps the next training steps.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_write:
            self._thread = threading.Thread(
                target=self._do_save, args=(step, host_tree, extra), daemon=True)
            self._thread.start()
        else:
            self._do_save(step, host_tree, extra)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def restore(self, target, shardings=None):
        self.wait()
        return restore(self.dir, target, shardings=shardings)

"""Sharded checkpointing: atomic, async, keep-k, restore-with-resharding.

Layout per step::

    <dir>/step_000042/
        manifest.json      # treedef, shapes, dtypes, per-leaf crc32, step
        arrays.npz         # flattened leaves (process-local; single-host here)
    <dir>/LATEST           # atomic pointer file

Fault-tolerance contract: writes go to ``step_X.tmp`` then ``os.replace`` —
a crash mid-write never corrupts the LATEST checkpoint.  Every leaf's crc32
is recorded in the manifest; :func:`latest_step` and :func:`restore` treat a
step with a missing file, unparsable manifest, or checksum mismatch as
*invalid* and fall back to the newest valid step (torn or bit-rotted
checkpoints are skipped, not loaded).  Save/restore are wrapped in a small
retry policy (``repro.resilience.retry``) so transient IO faults — including
injected ``ckpt.save`` / ``ckpt.restore`` chaos faults — don't kill a run.

Restore accepts a different mesh (elastic): leaves are re-placed with the
target shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.obs.metrics import registry as _obs
from repro.resilience import chaos as _chaos
from repro.resilience.retry import Policy

__all__ = [
    "save", "restore", "latest_step", "valid_steps", "CheckpointError",
    "CheckpointManager",
]


class CheckpointError(RuntimeError):
    """A checkpoint step exists but failed validation (torn write, checksum
    mismatch, unparsable manifest)."""


#: retry policy for checkpoint IO: transient faults (disk hiccups, injected
#: chaos) get three attempts with a short backoff before surfacing.
IO_POLICY = Policy(max_attempts=3, base_delay=0.05,
                   retry_on=(OSError, _chaos.ChaosError))

# numpy can't round-trip ml_dtypes through savez; store raw views + dtype
_EXOTIC = {}
try:
    import ml_dtypes
    _EXOTIC = {
        "bfloat16": ml_dtypes.bfloat16,
        "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
        "float8_e5m2": ml_dtypes.float8_e5m2,
    }
except ImportError:  # pragma: no cover
    pass


def _to_storable(a: np.ndarray) -> np.ndarray:
    if str(a.dtype) in _EXOTIC:
        return a.view(np.uint8).reshape(a.shape + (a.dtype.itemsize,))
    return a


def _from_storable(a: np.ndarray, dtype_str: str, shape) -> np.ndarray:
    if dtype_str in _EXOTIC:
        return a.reshape(-1).view(_EXOTIC[dtype_str]).reshape(shape)
    return a


def _paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


def _leaf_crc(a: np.ndarray) -> int:
    """crc32 of a *storable* leaf's bytes (what actually hits disk)."""
    return zlib.crc32(np.ascontiguousarray(a).tobytes()) & 0xFFFFFFFF


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    return IO_POLICY.call(_save_once, ckpt_dir, step, tree, extra,
                          site="ckpt.save")


def _save_once(ckpt_dir: str, step: int, tree: Any,
               extra: Optional[dict]) -> str:
    _chaos.maybe_raise("ckpt.save")
    os.makedirs(ckpt_dir, exist_ok=True)
    final = _step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(tree)
    raw = [np.asarray(jax.device_get(x)) for x in leaves]
    host_leaves = [_to_storable(a) for a in raw]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
    manifest = {
        "step": step,
        "paths": _paths(tree),
        "shapes": [list(a.shape) for a in raw],
        "dtypes": [str(a.dtype) for a in raw],
        "checksums": [_leaf_crc(a) for a in host_leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def _validate_step(ckpt_dir: str, step: int) -> Optional[str]:
    """None if the step directory is a loadable checkpoint, else the reason
    it isn't (``"partial"`` / ``"manifest"`` / ``"arrays"`` / ``"checksum"``)."""
    d = _step_dir(ckpt_dir, step)
    mpath, apath = os.path.join(d, "manifest.json"), os.path.join(d, "arrays.npz")
    if not (os.path.isfile(mpath) and os.path.isfile(apath)):
        return "partial"
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        n_leaves = len(manifest["paths"])
        sums = manifest.get("checksums")
    except (OSError, ValueError, KeyError, TypeError):
        return "manifest"
    try:
        with np.load(apath) as data:
            for i in range(n_leaves):
                a = data[f"leaf_{i}"]
                if sums is not None and _leaf_crc(a) != int(sums[i]):
                    return "checksum"
    except Exception:
        return "arrays"
    return None


def valid_steps(ckpt_dir: str) -> list[int]:
    """All steps on disk that pass validation, ascending."""
    return [s for s in _list_steps(ckpt_dir)
            if _validate_step(ckpt_dir, s) is None]


def _list_steps(ckpt_dir: str) -> list[int]:
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    steps = []
    for nm in names:
        if nm.startswith("step_") and not nm.endswith(".tmp"):
            try:
                steps.append(int(nm.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *valid* step: the LATEST pointer if it checks out, else the
    newest on-disk step that does.  Invalid candidates (torn writes,
    checksum failures) are skipped with a ``ckpt.skipped`` counter."""
    candidates = []
    p = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(p):
        try:
            with open(p) as f:
                candidates.append(int(f.read().strip()))
        except (OSError, ValueError):
            pass
    for s in reversed(_list_steps(ckpt_dir)):
        if s not in candidates:
            candidates.append(s)
    for s in candidates:
        reason = _validate_step(ckpt_dir, s)
        if reason is None:
            return s
        _obs.counter(
            "ckpt.skipped", "checkpoint steps skipped as invalid on load"
        ).inc(1, reason=reason)
    return None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``target``.  ``shardings`` (same
    structure or a single sharding) enables elastic re-mesh on load.

    With ``step=None`` the newest *valid* checkpoint is loaded — partial or
    checksum-failing steps are skipped.  An explicit ``step`` is validated
    and raises :class:`CheckpointError` if it doesn't check out."""
    return IO_POLICY.call(_restore_once, ckpt_dir, target, step, shardings,
                          site="ckpt.restore")


def _restore_once(ckpt_dir: str, target: Any, step: Optional[int],
                  shardings: Any) -> tuple[Any, int, dict]:
    _chaos.maybe_raise("ckpt.restore")
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint in {ckpt_dir}")
    else:
        reason = _validate_step(ckpt_dir, step)
        if reason is not None:
            raise CheckpointError(
                f"checkpoint step {step} in {ckpt_dir} failed validation "
                f"({reason})")
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    host_leaves = [
        _from_storable(data[f"leaf_{i}"], manifest["dtypes"][i],
                       tuple(manifest["shapes"][i]))
        for i in range(len(manifest["paths"]))
    ]
    _, treedef = jax.tree.flatten(target)
    if treedef.num_leaves != len(host_leaves):
        raise ValueError(
            f"checkpoint has {len(host_leaves)} leaves, target expects "
            f"{treedef.num_leaves}")
    if shardings is not None:
        is_sh = lambda x: isinstance(x, jax.sharding.Sharding)
        shard_leaves = jax.tree.leaves(shardings, is_leaf=is_sh)
        if len(shard_leaves) == 1:
            shard_leaves = shard_leaves * len(host_leaves)
        leaves = [jax.device_put(a, s) for a, s in zip(host_leaves, shard_leaves)]
    else:
        leaves = [jax.device_put(a) for a in host_leaves]
    return jax.tree.unflatten(treedef, leaves), step, manifest["extra"]


class CheckpointManager:
    """Async writer with keep-k GC and crash-safe commits.

    A failure on the background writer thread is recorded and re-raised on
    the next :meth:`save` / :meth:`wait` / :meth:`restore` call — async
    write errors are surfaced, never swallowed."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_write: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _do_save(self, step, host_tree, extra):
        try:
            save(self.dir, step, host_tree, extra)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            _obs.counter(
                "ckpt.async_errors", "failures on the async checkpoint writer"
            ).inc(1, error=type(e).__name__)
            self._error = e

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # device_get happens synchronously (consistent snapshot); the disk
        # write overlaps the next training steps.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_write:
            self._thread = threading.Thread(
                target=self._do_save, args=(step, host_tree, extra), daemon=True)
            self._thread.start()
        else:
            self._do_save(step, host_tree, extra)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def restore(self, target, shardings=None):
        self.wait()
        return restore(self.dir, target, shardings=shardings)

"""Retry / backoff / timeout policies for the IO and serving paths.

A :class:`Policy` is a small frozen config; ``policy.call(fn, site=...)``
runs ``fn`` up to ``max_attempts`` times with exponential backoff.  The
jitter is *deterministic* (hashed from site + attempt), so a chaos run at
a pinned seed replays identically.  Timeouts are enforced with a daemon
worker thread — the only portable option for arbitrary Python callables;
a timed-out callable keeps running in the background and its thread is
leaked deliberately (documented, daemonic, bounded by process exit).

Observability: ``resilience.retries{site,error}`` per retried failure,
``resilience.retry_exhausted{site}`` when a call gives up, and
``resilience.timeouts{site}`` per timeout.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import threading
import time
from typing import Callable, Optional, Tuple

from repro.obs.metrics import registry as _obs

__all__ = ["Policy", "retry", "call_with_timeout"]


def call_with_timeout(fn: Callable, timeout: Optional[float], *args, **kw):
    """Run ``fn(*args, **kw)``, raising :class:`TimeoutError` after
    ``timeout`` seconds (``None``/``<=0`` disables the guard)."""
    if not timeout or timeout <= 0:
        return fn(*args, **kw)
    box: dict = {}

    def _run():
        try:
            box["value"] = fn(*args, **kw)
        except BaseException as e:  # re-raised on the caller's thread
            box["error"] = e

    t = threading.Thread(target=_run, daemon=True)
    t.start()
    t.join(timeout)
    if t.is_alive():
        raise TimeoutError(
            f"call exceeded {timeout:g}s (worker thread abandoned)")
    if "error" in box:
        raise box["error"]
    return box["value"]


def _jitter_frac(site: str, attempt: int) -> float:
    h = hashlib.sha256(f"repro.retry:{site}:{attempt}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0**64


@dataclasses.dataclass(frozen=True)
class Policy:
    """Retry policy: ``max_attempts`` tries, exponential backoff with
    deterministic jitter, optional per-attempt ``timeout``."""

    max_attempts: int = 3
    base_delay: float = 0.05
    backoff: float = 2.0
    jitter: float = 0.25
    timeout: Optional[float] = None
    retry_on: Tuple[type, ...] = (Exception,)

    def delay(self, site: str, attempt: int) -> float:
        d = self.base_delay * self.backoff ** attempt
        return d * (1.0 + self.jitter * _jitter_frac(site, attempt))

    def call(self, fn: Callable, *args, site: str = "retry", **kw):
        last: Optional[BaseException] = None
        for attempt in range(max(self.max_attempts, 1)):
            try:
                return call_with_timeout(fn, self.timeout, *args, **kw)
            except TimeoutError as e:
                _obs.counter("resilience.timeouts",
                             "timed-out resilient calls").inc(site=site)
                last = e
            except self.retry_on as e:
                last = e
            if attempt + 1 >= max(self.max_attempts, 1):
                break
            _obs.counter(
                "resilience.retries", "retried failures by site"
            ).inc(site=site, error=type(last).__name__)
            time.sleep(self.delay(site, attempt))
        _obs.counter("resilience.retry_exhausted",
                     "calls that exhausted their retry budget").inc(site=site)
        raise last


def retry(policy: Optional[Policy] = None, site: str = "retry", **overrides):
    """Decorator form: ``@retry(Policy(max_attempts=5), site="ckpt.save")``
    or ``@retry(site="x", max_attempts=2)``."""
    pol = policy or Policy()
    if overrides:
        pol = dataclasses.replace(pol, **overrides)

    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kw):
            return pol.call(fn, *args, site=site, **kw)

        wrapped.policy = pol
        return wrapped

    return deco

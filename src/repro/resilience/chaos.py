"""Deterministic, seed-driven fault injection.

Two ways to arm it:

* **Environment** — ``REPRO_CHAOS=<seed>:<rate>`` enables rate-based
  injection at every *default* site (``REPRO_CHAOS_SITES=a,b,c``
  restricts or extends the set; opt-in sites like ``tune.trial`` must be
  named explicitly).  The decision at a site is a pure function of
  ``(seed, site, per-site call index)`` — two runs with the same seed and
  the same call sequence inject the *same* faults, which is what lets CI
  run the whole tier-1 suite under chaos at a pinned seed.
* **Programmatic** — :func:`inject` queues an exception (by default a
  :class:`ChaosError`) for the next N checks of a site, regardless of the
  env configuration.  Tests use this to force a specific failure exactly
  once.

Sites call :func:`maybe_raise` at dispatch time (host Python — safe at
jit trace time, where a raised fault aborts the trace and is caught by
the degradation ladder in :mod:`repro.resilience.degrade`).  Every
injection increments ``resilience.chaos_injected{site}``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from typing import Optional

from repro.obs.metrics import registry as _obs

__all__ = [
    "ChaosError",
    "DEFAULT_SITES",
    "KNOWN_SITES",
    "configure",
    "configure_spec",
    "enabled",
    "active_for",
    "inject",
    "maybe_raise",
    "reset",
]

ENV_SPEC = "REPRO_CHAOS"
ENV_SITES = "REPRO_CHAOS_SITES"

#: sites armed by rate-based injection when ``REPRO_CHAOS_SITES`` is unset.
#: Every one of them sits on a *recoverable* path (degradation ladder,
#: retry policy, or quarantine-and-rebuild), so a chaos run of the test
#: suite exercises fallbacks rather than manufacturing unhandled crashes.
DEFAULT_SITES = frozenset({
    "kernel.tocab_fused",   # fused-impl dispatch in repro.core.tocab
    "kernel.tocab_spmm",    # dense-bin Pallas dispatch in repro.core.balance
    "ckpt.save",            # train/checkpoint.py write path (retried)
    "ckpt.restore",         # train/checkpoint.py read path (retried)
    "tune.db_load",         # tune/db.py load (retried, quarantine on corrupt)
    "tune.db_save",         # tune/db.py save (retried, degrade to in-process)
    "serve.batch",          # launch/serve.py per-batch step (retried)
})

#: every named site, including the opt-in ones rate-based injection skips
#: unless ``REPRO_CHAOS_SITES`` names them.
KNOWN_SITES = tuple(sorted(DEFAULT_SITES | {
    "kernel.tocab_slab",        # slab rung of the ladder (opt-in)
    "kernel.tocab_fused.op",    # kernels/tocab_fused ops entry (opt-in)
    "kernel.tocab_spmm.op",     # kernels/tocab_spmm ops entry (opt-in)
    "tune.trial",               # tuner trial execution (opt-in)
}))


class ChaosError(RuntimeError):
    """The fault :func:`maybe_raise` injects (rate-based or default queued)."""

    def __init__(self, site: str, seq: int = -1):
        self.site = site
        self.seq = seq
        super().__init__(f"chaos fault injected at site {site!r} (call #{seq})")


@dataclasses.dataclass(frozen=True)
class _Config:
    seed: int
    rate: float
    sites: frozenset


_lock = threading.Lock()
_cfg: Optional[_Config] = None  # programmatic override
_env_cfg: Optional[_Config] = None  # parsed REPRO_CHAOS (cached)
_env_parsed = False
_counters: dict = {}  # site -> call count (only while a config is active)
_queued: dict = {}  # site -> [exceptions]


def configure_spec(spec: str, sites: Optional[str] = None) -> _Config:
    """Parse ``"<seed>:<rate>"`` (+ optional comma-joined site list) and
    install it as the active configuration."""
    seed_s, _, rate_s = spec.partition(":")
    seed = int(seed_s)
    rate = float(rate_s) if rate_s else 1.0
    site_set = (
        frozenset(s.strip() for s in sites.split(",") if s.strip())
        if sites else DEFAULT_SITES)
    return configure(seed=seed, rate=rate, sites=site_set)


def configure(seed: int, rate: float, sites=None) -> _Config:
    """Programmatically arm rate-based injection (overrides the env)."""
    global _cfg
    cfg = _Config(seed=int(seed), rate=float(rate),
                  sites=frozenset(sites) if sites else DEFAULT_SITES)
    with _lock:
        _cfg = cfg
        _counters.clear()
    return cfg


def _from_env() -> Optional[_Config]:
    global _env_cfg, _env_parsed
    if _env_parsed:
        return _env_cfg
    spec = os.environ.get(ENV_SPEC)
    cfg = None
    if spec:
        try:
            seed_s, _, rate_s = spec.partition(":")
            seed, rate = int(seed_s), float(rate_s) if rate_s else 1.0
            sites = os.environ.get(ENV_SITES)
            site_set = (
                frozenset(s.strip() for s in sites.split(",") if s.strip())
                if sites else DEFAULT_SITES)
            cfg = _Config(seed=seed, rate=rate, sites=site_set)
        except ValueError:
            raise ValueError(
                f"{ENV_SPEC}={spec!r}: expected '<seed>:<rate>' "
                "(e.g. REPRO_CHAOS=1234:0.1)") from None
    with _lock:
        _env_cfg, _env_parsed = cfg, True
    return cfg


def _active() -> Optional[_Config]:
    return _cfg if _cfg is not None else _from_env()


def enabled() -> bool:
    """True when rate-based injection is armed (env or programmatic)."""
    cfg = _active()
    return cfg is not None and cfg.rate > 0


def active_for(site: str) -> bool:
    """True when rate-based injection can fire at ``site`` — tests that
    assert *which* engine ran (not its results) skip under this."""
    cfg = _active()
    return cfg is not None and cfg.rate > 0 and site in cfg.sites


def inject(site: str, exc: Optional[BaseException] = None, times: int = 1):
    """Queue ``exc`` (default: a :class:`ChaosError`) for the next
    ``times`` checks of ``site`` — independent of the env configuration."""
    with _lock:
        q = _queued.setdefault(site, [])
        for _ in range(max(times, 1)):
            q.append(exc if exc is not None else ChaosError(site))


def _draw(seed: int, site: str, seq: int) -> float:
    h = hashlib.sha256(f"repro.chaos:{seed}:{site}:{seq}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0**64


def maybe_raise(site: str):
    """Fault-injection check point.  Drains the programmatic queue first,
    then rolls the deterministic (seed, site, call-index) die."""
    if _queued:
        with _lock:
            q = _queued.get(site)
            exc = q.pop(0) if q else None
            if q is not None and not q:
                _queued.pop(site, None)
        if exc is not None:
            _obs.counter(
                "resilience.chaos_injected", "faults injected by site"
            ).inc(site=site, mode="queued")
            raise exc
    cfg = _active()
    if cfg is None or cfg.rate <= 0 or site not in cfg.sites:
        return
    with _lock:
        seq = _counters.get(site, 0)
        _counters[site] = seq + 1
    if _draw(cfg.seed, site, seq) < cfg.rate:
        _obs.counter(
            "resilience.chaos_injected", "faults injected by site"
        ).inc(site=site, mode="rate")
        raise ChaosError(site, seq)


def reset():
    """Disarm everything and forget call counts (tests; also re-reads the
    env on the next check)."""
    global _cfg, _env_cfg, _env_parsed
    with _lock:
        _cfg = None
        _env_cfg, _env_parsed = None, False
        _counters.clear()
        _queued.clear()

"""Engine degradation ladder: fused → slab → reference.

Gunrock-style frameworks survive in production because every specialized
kernel has a baseline to fall back on.  Here the ladder is expressed as an
ordered list of *rungs* — ``(name, thunk)`` pairs — and :func:`dispatch`
walks it: the first rung that returns wins; a rung that raises (a Pallas
lowering failure, an injected chaos fault, a jit compile error) records a
``resilience.fallbacks{site,from,to}`` counter and hands off to the next.

The verdict is **memoized per (graph fingerprint, dispatch site)**: once
fused is known-broken for a graph, every later call — including
``impl="auto"`` resolution in ``pagerank``/``spmv`` — starts at the
working rung instead of re-failing once per iteration or per trace.

``allow_fallback`` semantics (:func:`fallback_allowed`):

* ``True``/``False`` — explicit caller choice, wins outright;
* ``None`` + the impl argument was ``"auto"`` — fallback on (the caller
  delegated the engine choice, so it accepts a degraded one);
* ``None`` + an explicit impl — fallback only when the
  ``REPRO_RESILIENCE_FALLBACK`` env var is truthy (the chaos-smoke CI job
  sets it so explicitly-fused tests degrade instead of dying).
"""
from __future__ import annotations

import os
import threading
from typing import Optional, Sequence, Tuple

from repro.obs.metrics import registry as _obs

__all__ = [
    "LADDER",
    "ENV_FALLBACK",
    "fallback_allowed",
    "apply_verdict",
    "record_verdict",
    "dispatch",
    "clear",
]

#: canonical rung order, strongest (most specialized) first
LADDER = ("fused", "slab", "reference")

ENV_FALLBACK = "REPRO_RESILIENCE_FALLBACK"

_lock = threading.Lock()
# (graph fingerprint, dispatch site) -> rung name decided by a past failure
_VERDICTS: dict = {}


def fallback_allowed(requested: str, allow_fallback: Optional[bool]) -> bool:
    """Resolve the ladder opt-in for one dispatch (see module docstring).
    ``requested`` is the caller's *pre-resolution* impl argument."""
    if allow_fallback is not None:
        return bool(allow_fallback)
    if requested == "auto":
        return True
    return os.environ.get(ENV_FALLBACK, "").lower() in ("1", "true", "yes")


def apply_verdict(fp: Optional[str], site: str, impl: str) -> str:
    """Skip straight to a memoized verdict: if a past dispatch for this
    (graph, site) degraded below ``impl``, return the decided rung."""
    if fp is None:
        return impl
    v = _VERDICTS.get((fp, site))
    if v is None or v not in LADDER or impl not in LADDER:
        return impl
    return v if LADDER.index(v) > LADDER.index(impl) else impl


def record_verdict(fp: Optional[str], site: str, rung: str):
    if fp is None:
        return
    with _lock:
        _VERDICTS[(fp, site)] = rung


def dispatch(site: str, fp: Optional[str],
             rungs: Sequence[Tuple[str, callable]],
             allow_fallback: bool = True):
    """Run the first working rung of ``rungs``; on failure fall through,
    recording the fallback and memoizing the landing rung.  With
    ``allow_fallback=False`` (or on the last rung) the failure propagates
    unchanged."""
    names = [n for n, _ in rungs]
    start = 0
    if fp is not None:
        v = _VERDICTS.get((fp, site))
        if v in names:
            start = names.index(v)
    for i in range(start, len(rungs)):
        name, thunk = rungs[i]
        try:
            return thunk()
        except Exception as e:
            if not allow_fallback or i + 1 >= len(rungs):
                raise
            nxt = names[i + 1]
            _obs.counter(
                "resilience.fallbacks",
                "engine degradations by dispatch site",
            ).inc(site=site, error=type(e).__name__,
                  **{"from": name, "to": nxt})
            record_verdict(fp, site, nxt)
    raise RuntimeError(f"{site}: empty degradation ladder")  # unreachable


def clear():
    """Forget memoized verdicts (tests / after a backend change)."""
    with _lock:
        _VERDICTS.clear()

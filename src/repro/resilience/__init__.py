"""Process-wide resilience layer: fault injection, degradation, retries.

GraphCage's premise is *choosing among engine variants* per workload; a
production deployment additionally has to survive any one of them failing
— a Pallas lowering error on a new backend, a corrupt tuning DB, a torn
checkpoint, a flaky filesystem.  This package turns every such "works on
my backend" assumption into a tested degradation path:

* :mod:`repro.resilience.chaos` — deterministic, seed-driven fault
  injection (``REPRO_CHAOS=<seed>:<rate>`` or programmatic
  :func:`~repro.resilience.chaos.inject`) with named sites in kernel
  dispatch, tuner trials, tune-DB and checkpoint IO, and the serve batch
  path.
* :mod:`repro.resilience.degrade` — the engine degradation ladder
  (fused → slab → reference) behind ``impl="auto"`` and
  ``allow_fallback=True``, with per-(graph, engine) verdict memoization
  and ``resilience.fallbacks`` obs counters.
* :mod:`repro.resilience.retry` — retry/backoff/timeout policies for
  checkpoint IO, tune-DB persistence, tuner trials, and serving.

Everything records into :data:`repro.obs.metrics.registry` under the
``resilience.*`` metric names rather than printing ad-hoc warnings.
"""
from . import chaos, degrade, retry  # noqa: F401
from .chaos import ChaosError  # noqa: F401
from .retry import Policy  # noqa: F401

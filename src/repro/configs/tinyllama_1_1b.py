"""Config for ``--arch tinyllama-1.1b`` (see lm_archs.py for the spec)."""
from . import get_arch

ARCH_ID = "tinyllama-1.1b"
SPEC = get_arch(ARCH_ID)
make_model_cfg = SPEC.make_model_cfg
make_smoke_cfg = SPEC.make_smoke_cfg
SHAPES = SPEC.shapes

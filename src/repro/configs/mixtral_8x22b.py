"""Config for ``--arch mixtral-8x22b`` (see lm_archs.py for the spec)."""
from . import get_arch

ARCH_ID = "mixtral-8x22b"
SPEC = get_arch(ARCH_ID)
make_model_cfg = SPEC.make_model_cfg
make_smoke_cfg = SPEC.make_smoke_cfg
SHAPES = SPEC.shapes

"""The four assigned GNN configs + the recsys config."""
from __future__ import annotations

from repro.models.gnn import GNNConfig
from repro.models.bert4rec import Bert4RecCfg
from .base import ArchSpec, GNN_SHAPES, RECSYS_SHAPES


def _gat():
    # [arXiv:1710.10903] 2 layers, 8 hidden per head, 8 heads, attn agg
    return GNNConfig(arch="gat", n_layers=2, d_in=1433, d_hidden=8,
                     n_classes=7, n_heads=8, agg="tocab")


def _gat_smoke():
    return GNNConfig(arch="gat", n_layers=2, d_in=16, d_hidden=4,
                     n_classes=4, n_heads=2)


def _gin():
    # [arXiv:1810.00826] 5 layers, 64 hidden, sum agg, learnable eps
    return GNNConfig(arch="gin", n_layers=5, d_in=1433, d_hidden=64,
                     n_classes=7, agg="tocab")


def _gin_smoke():
    return GNNConfig(arch="gin", n_layers=2, d_in=16, d_hidden=8, n_classes=4)


def _dimenet():
    # [arXiv:2003.03123] 6 blocks, 128 hidden, 8 bilinear, 7 spherical, 6 radial
    return GNNConfig(arch="dimenet", n_layers=0, d_in=16, d_hidden=128,
                     n_classes=1, n_blocks=6, n_bilinear=8, n_spherical=7,
                     n_radial=6, graph_level=True)


def _dimenet_smoke():
    return GNNConfig(arch="dimenet", n_layers=0, d_in=4, d_hidden=16,
                     n_classes=1, n_blocks=2, n_bilinear=4, n_spherical=3,
                     n_radial=4, graph_level=True)


def _sage():
    # [arXiv:1706.02216] 2 layers, 128 hidden, mean agg, fanout 25-10
    return GNNConfig(arch="sage", n_layers=2, d_in=602, d_hidden=128,
                     n_classes=41, sample_sizes=(25, 10), agg="tocab")


def _sage_smoke():
    return GNNConfig(arch="sage", n_layers=2, d_in=8, d_hidden=16,
                     n_classes=4, sample_sizes=(3, 2))


def _bert4rec():
    # [arXiv:1904.06690] d=64, 2 blocks, 2 heads, L=200; 1M-item table per
    # the recsys huge-table regime
    return Bert4RecCfg(name="bert4rec", vocab=1_000_000, max_len=200,
                       d_model=64, n_blocks=2, n_heads=2)


def _bert4rec_smoke():
    return Bert4RecCfg(name="bert4rec-smoke", vocab=1000, max_len=32,
                       d_model=32, n_blocks=2, n_heads=2)


GNN_ARCHS = {
    "gat-cora": ArchSpec("gat-cora", "gnn", _gat, _gat_smoke, GNN_SHAPES,
                         source="arXiv:1710.10903"),
    "gin-tu": ArchSpec("gin-tu", "gnn", _gin, _gin_smoke, GNN_SHAPES,
                       source="arXiv:1810.00826"),
    "dimenet": ArchSpec(
        "dimenet", "gnn", _dimenet, _dimenet_smoke, GNN_SHAPES,
        source="arXiv:2003.03123",
        notes="triplets capped at 8/edge for the two huge shapes "
              "(DESIGN.md §Arch-applicability)"),
    "graphsage-reddit": ArchSpec(
        "graphsage-reddit", "gnn", _sage, _sage_smoke, GNN_SHAPES,
        source="arXiv:1706.02216"),
}

RECSYS_ARCHS = {
    "bert4rec": ArchSpec("bert4rec", "recsys", _bert4rec, _bert4rec_smoke,
                         RECSYS_SHAPES, source="arXiv:1904.06690"),
}

"""Architecture registry: ``get_arch(id)`` + per-arch config modules.

Ten assigned architectures (``--arch <id>``):
  LM:     granite-moe-3b-a800m, mixtral-8x22b, tinyllama-1.1b,
          gemma-7b, gemma2-27b
  GNN:    gat-cora, gin-tu, dimenet, graphsage-reddit
  RecSys: bert4rec
plus the paper's own graph-algorithm suite config (``graphcage``).
"""
from .base import ArchSpec, ShapeCell, LM_SHAPES, GNN_SHAPES, RECSYS_SHAPES  # noqa: F401
from .lm_archs import LM_ARCHS
from .gnn_archs import GNN_ARCHS, RECSYS_ARCHS

ARCHS: dict[str, ArchSpec] = {**LM_ARCHS, **GNN_ARCHS, **RECSYS_ARCHS}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells(include_skipped: bool = False):
    """Every (arch × shape) cell; skipped cells flagged."""
    for arch_id, spec in ARCHS.items():
        for cell in spec.shapes:
            skipped = cell.name in spec.skip_shapes
            if skipped and not include_skipped:
                continue
            yield arch_id, cell, skipped

"""Config schema: every assigned architecture is an ``ArchSpec`` with its
exact literature config, a reduced smoke config, and its shape set."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["ShapeCell", "ArchSpec", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode | gnn_full | gnn_minibatch |
    #            gnn_molecule | recsys_train | recsys_serve | recsys_retrieval
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # GNN fields
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple = ()
    n_graphs: int = 0
    nodes_per_graph: int = 0
    edges_per_graph: int = 0
    # recsys fields
    batch: int = 0
    n_candidates: int = 0


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys
    make_model_cfg: Callable[[], Any]
    make_smoke_cfg: Callable[[], Any]
    shapes: tuple
    source: str = ""
    notes: str = ""
    # archs whose attention is purely global skip long_500k (per assignment)
    skip_shapes: tuple = ()


# ------------------------- shared shape sets ------------------------- #
LM_SHAPES = (
    ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeCell("long_500k", "decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeCell("full_graph_sm", "gnn_full", n_nodes=2708, n_edges=10556,
              d_feat=1433),
    ShapeCell("minibatch_lg", "gnn_minibatch", n_nodes=232965,
              n_edges=114615892, d_feat=602, batch_nodes=1024,
              fanout=(15, 10)),
    ShapeCell("ogb_products", "gnn_full", n_nodes=2449029, n_edges=61859140,
              d_feat=100),
    ShapeCell("molecule", "gnn_molecule", n_graphs=128, nodes_per_graph=30,
              edges_per_graph=64, d_feat=16),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "recsys_train", batch=65536),
    ShapeCell("serve_p99", "recsys_serve", batch=512),
    ShapeCell("serve_bulk", "recsys_serve", batch=262144),
    ShapeCell("retrieval_cand", "recsys_retrieval", batch=1,
              n_candidates=1_000_000),
)

"""Config for ``--arch dimenet`` (see gnn_archs.py for the spec)."""
from . import get_arch

ARCH_ID = "dimenet"
SPEC = get_arch(ARCH_ID)
make_model_cfg = SPEC.make_model_cfg
make_smoke_cfg = SPEC.make_smoke_cfg
SHAPES = SPEC.shapes

"""The five assigned LM-family transformer configs (exact literature specs).

Per-arch ``long_500k`` policy (assignment + DESIGN.md §Arch-applicability):
pure global-attention archs skip it; Mixtral (SWA) and Gemma-2
(local/global alternating) run it.
"""
from __future__ import annotations

from repro.models.transformer import TransformerCfg
from .base import ArchSpec, LM_SHAPES


def _granite():
    # [hf:ibm-granite/granite-3.0-*-base] — assignment spec; the inline note
    # says "40e top-8" in the primary field and "32 experts" in the comment;
    # we follow the primary field (40 experts, top-8).
    return TransformerCfg(
        name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
        n_kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
        mlp_kind="swiglu", num_experts=40, top_k=8, layer_pattern="global",
    )


def _granite_smoke():
    return TransformerCfg(
        name="granite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32, vocab=512, mlp_kind="swiglu",
        num_experts=8, top_k=2, remat=False,
    )


def _mixtral():
    # [arXiv:2401.04088] 8 experts top-2; SWA per assignment (window 4096)
    return TransformerCfg(
        name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, head_dim=128, d_ff=16384, vocab=32768,
        mlp_kind="swiglu", num_experts=8, top_k=2,
        layer_pattern="window", window=4096, rope_theta=1e6,
    )


def _mixtral_smoke():
    return TransformerCfg(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=4,
        head_dim=8, d_ff=128, vocab=512, mlp_kind="swiglu",
        num_experts=4, top_k=2, layer_pattern="window", window=16, remat=False,
    )


def _tinyllama():
    # [arXiv:2401.02385] llama2-arch small
    return TransformerCfg(
        name="tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32,
        n_kv_heads=4, head_dim=64, d_ff=5632, vocab=32000,
        mlp_kind="swiglu", layer_pattern="global",
    )


def _tinyllama_smoke():
    return TransformerCfg(
        name="tinyllama-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=176, vocab=512,
        mlp_kind="swiglu", remat=False,
    )


def _gemma7b():
    # [arXiv:2403.08295] GeGLU, head_dim=256, 16 q heads / 16 kv heads
    return TransformerCfg(
        name="gemma-7b", n_layers=28, d_model=3072, n_heads=16,
        n_kv_heads=16, head_dim=256, d_ff=24576, vocab=256000,
        mlp_kind="geglu", norm_plus_one=True, embed_scale=True,
        layer_pattern="global",
    )


def _gemma7b_smoke():
    return TransformerCfg(
        name="gemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=512, mlp_kind="geglu",
        norm_plus_one=True, embed_scale=True, remat=False,
    )


def _gemma2_27b():
    # [arXiv:2408.00118] local(4096)+global alternating, logit softcaps,
    # query scale = (d_model/n_heads)^-0.5 = 144^-0.5
    return TransformerCfg(
        name="gemma2-27b", n_layers=46, d_model=4608, n_heads=32,
        n_kv_heads=16, head_dim=128, d_ff=36864, vocab=256000,
        mlp_kind="geglu", norm_plus_one=True, embed_scale=True,
        layer_pattern="alternating", window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        attn_scale=(4608 / 32) ** -0.5,
    )


def _gemma2_smoke():
    return TransformerCfg(
        name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=256, vocab=512, mlp_kind="geglu",
        norm_plus_one=True, embed_scale=True, layer_pattern="alternating",
        window=16, attn_softcap=50.0, final_softcap=30.0, remat=False,
    )


LM_ARCHS = {
    "granite-moe-3b-a800m": ArchSpec(
        "granite-moe-3b-a800m", "lm", _granite, _granite_smoke, LM_SHAPES,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        skip_shapes=("long_500k",),
        notes="pure global attention → long_500k skipped per assignment"),
    "mixtral-8x22b": ArchSpec(
        "mixtral-8x22b", "lm", _mixtral, _mixtral_smoke, LM_SHAPES,
        source="arXiv:2401.04088",
        notes="SWA(4096) bounds decode KV → long_500k runs with ring cache"),
    "tinyllama-1.1b": ArchSpec(
        "tinyllama-1.1b", "lm", _tinyllama, _tinyllama_smoke, LM_SHAPES,
        source="arXiv:2401.02385", skip_shapes=("long_500k",),
        notes="pure global attention → long_500k skipped per assignment"),
    "gemma-7b": ArchSpec(
        "gemma-7b", "lm", _gemma7b, _gemma7b_smoke, LM_SHAPES,
        source="arXiv:2403.08295", skip_shapes=("long_500k",),
        notes="pure global attention → long_500k skipped per assignment"),
    "gemma2-27b": ArchSpec(
        "gemma2-27b", "lm", _gemma2_27b, _gemma2_smoke, LM_SHAPES,
        source="arXiv:2408.00118",
        notes="alternating local/global: local ring cache + global full KV"),
}

"""The paper's own configuration: the GraphCage graph-algorithm suite.

Mirrors the evaluation setup of the paper (§4): PR / SpMV / BC over a suite
of scale-free graphs, TOCAB block size as the tunable (Fig. 11), plus the
cache-model parameters of the GTX 1080Ti the paper measured on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class GraphCageCfg:
    # graph suite (scaled-down, same generator family as Kron21/Twitter)
    scales: tuple = (14, 15, 16)
    edge_factor: int = 8
    # TOCAB
    block_size: int = 8192  # vertices per subgraph (Fig. 11 sweep default)
    fast_mem_bytes: int = 4 * 1024 * 1024  # TPU VMEM budget for the window
    # paper GPU cache model (Fig. 9/10)
    llc_bytes: int = int(2.75 * 1024 * 1024)
    line_bytes: int = 128
    ways: int = 16
    # algorithms
    pr_damping: float = 0.85
    pr_tol: float = 1e-6
    bfs_alpha: float = 15.0
    # autotuner (repro.tune) — the Fig. 11 sensitivity axes the search
    # sweeps around this config's defaults, and where the DB persists
    tune_block_sizes: tuple = (1024, 2048, 4096, 8192, 16384)
    tune_alphas: tuple = (4.0, 15.0, 64.0)
    tune_impls: tuple = ("slab", "fused")
    tune_db_dir: str = "experiments/tune"
    # resilience (repro.resilience) — retry budget for IO paths, the
    # per-candidate tuner wall-clock bound (None = unbounded), and whether
    # explicitly-requested impls may degrade down the engine ladder
    retry_attempts: int = 3
    retry_base_delay: float = 0.05
    trial_timeout_s: Optional[float] = None
    allow_engine_fallback: Optional[bool] = None  # None → env/impl-derived


DEFAULT = GraphCageCfg()

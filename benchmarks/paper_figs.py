"""Paper-figure reproductions (one function per table/figure).

CSV rows: ``name,us_per_call,derived``.  Speedups are normalized to the
Base (flat pull) implementation, mirroring Figs. 6-8; Figs. 9-10 come from
the analytic cache model; Fig. 11 sweeps the block size; Tables 3/4 report
per-iteration times and partition counts.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import (
    CacheConfig, bc, build_blocked, pagerank_iteration, simulate_pagerank_variant,
    spmv,
)
from .common import BLOCK_SIZE, SUITE, emit, get_graph, timeit

PR_VARIANTS = ("base", "push", "cb", "gc-pull", "gc-push")

# Which cache-model replay stream corresponds to each runtime PR variant
# (push variants share base's sparse-global-write stream shape).
_CACHE_VARIANT = {"base": "base", "push": "base", "cb": "cb",
                  "gc-pull": "tocab", "gc-push": "tocab"}
# Scaled LLC (|V|·4B / capacity matched to the paper's LiveJournal / 2.75MB).
_MODEL_CFG = CacheConfig(capacity_bytes=64 * 1024, line_bytes=128, ways=16)
_MODEL_BLOCK = 4096


def _pr_iter_time(name, variant):
    g, dg, bg, bgp = get_graph(name)
    bgv = bgp if variant == "gc-push" else bg
    rank = jnp.full((g.n,), 1.0 / g.n, jnp.float32)
    import jax
    fn = jax.jit(lambda r: pagerank_iteration(variant, dg, bgv, r,
                                              dg.out_degree))
    return timeit(fn, rank)


_CACHE_SIM: dict = {}


def _cache_counters(gname: str, variant: str) -> dict:
    """Analytic cache-model counters for one (graph, runtime-variant)."""
    cv = _CACHE_VARIANT[variant]
    key = (gname, cv)
    if key not in _CACHE_SIM:
        g, *_ = get_graph(gname)
        _CACHE_SIM[key] = simulate_pagerank_variant(
            g, cv, _MODEL_CFG, block_size=_MODEL_BLOCK)
    r = _CACHE_SIM[key]
    return dict(miss_rate=r["miss_rate"], cache_misses=r["cache_misses"],
                dram_per_edge=r["dram_per_edge"])


def fig5_accum():
    """Fig. 5 (accumulation): slab vs fused TOCAB accumulation.

    The slab path materialises a ``(num_blocks, local_budget)`` partial
    slab in HBM (phase 2) and segment-reduces it back (phase 3); the fused
    path keeps the accumulator tile resident and never writes partials.
    Reports the slab phase split, slab-vs-fused edges/s for pull and push,
    the cache model's DRAM-traffic prediction for both variants, and the
    partial-slab bytes the fused path never round-trips."""
    import jax
    from repro.core import tocab

    gname = "rmat14"  # the fig6-smoke graph
    g, dg, bg, bgp = get_graph(gname)
    x = jnp.ones((g.n,), jnp.float32)

    # Slab phase split (pull): phase-2 partials (the HBM slab write) vs
    # phase-3 flat segment reduce.
    p2 = jax.jit(lambda v: tocab.tocab_pull_partials(bg, v, "sum", None))
    partials = p2(x)
    slab_mb = partials.size * partials.dtype.itemsize / 2**20
    emit(f"fig5_accum/{gname}/pull/slab/phase2", timeit(p2, x),
         partial_slab_mb=slab_mb, blocks=bg.num_blocks)
    emit(f"fig5_accum/{gname}/pull/slab/phase3",
         timeit(jax.jit(lambda p: tocab.reduce_partials(bg, p)), partials))

    # End-to-end slab vs fused (one kernel, epilogue-fused apply elided).
    for direction, bgv, fn in (("pull", bg, tocab.tocab_pull),
                               ("push", bgp, tocab.tocab_push)):
        times = {
            impl: timeit(jax.jit(
                lambda v, i=impl, b=bgv, f=fn: f(b, v, impl=i)), x)
            for impl in ("slab", "fused")
        }
        for impl, us in times.items():
            emit(f"fig5_accum/{gname}/{direction}/{impl}", us,
                 speedup=times["slab"] / us,
                 edges_per_s=g.m / (us * 1e-6))

    # Cache-model prediction: the fused stream drops the partial-slab
    # write+read traffic entirely.
    model = {v: simulate_pagerank_variant(g, v, _MODEL_CFG,
                                          block_size=_MODEL_BLOCK)
             for v in ("tocab", "fused")}
    for v, r in model.items():
        emit(f"fig5_accum/{gname}/model/{v}", 0.0,
             dram_per_edge=r["dram_per_edge"],
             vs_slab=r["dram_per_edge"] / model["tocab"]["dram_per_edge"])


def fig6_pagerank():
    """Fig. 6: PR per-iteration speedup over Base, per graph × variant."""
    for gname in SUITE:
        g, *_ = get_graph(gname)
        base = _pr_iter_time(gname, "base")
        for v in PR_VARIANTS:
            us = base if v == "base" else _pr_iter_time(gname, v)
            emit(f"fig6/pr/{gname}/{v}", us,
                 speedup=base / us,
                 edges_per_s=g.m / (us * 1e-6),
                 **_cache_counters(gname, v))


def fig7_spmv():
    """Fig. 7: SpMV speedup over Base."""
    import jax
    for gname in SUITE:
        g, dg, bg, bgp = get_graph(gname)
        x = jnp.ones((g.n,), jnp.float32)
        times = {}
        for v in ("base", "cb", "gc-pull", "gc-push"):
            bgv = bgp if v == "gc-push" else bg
            fn = jax.jit(lambda xx, vv=v, bb=bgv: spmv(dg, bb, xx, variant=vv))
            times[v] = timeit(fn, x)
        for v, us in times.items():
            emit(f"fig7/spmv/{gname}/{v}", us,
                 speedup=times["base"] / us,
                 edges_per_s=g.m / (us * 1e-6))


def fig8_bc():
    """Fig. 8: BC (forward+backward) flat vs TOCAB-pull."""
    for gname in ("rmat14", "rmat15"):
        g, dg, bg, _ = get_graph(gname)
        t_flat = timeit(lambda: bc(dg, None, jnp.int32(0)))
        t_toc = timeit(lambda: bc(dg, bg, jnp.int32(0)))
        emit(f"fig8/bc/{gname}/flat", t_flat, speedup=1.0)
        emit(f"fig8/bc/{gname}/graphcage", t_toc, speedup=t_flat / t_toc)


def fig8_balance():
    """Fig. 8 (extended, §load-balancing): uniform vs sparsity-aware TOCAB
    scheduling, whole-engine and per-bin.  Blocks are classified by
    edges-per-row terciles; each bin runs its matched strategy (row-per-lane
    segmented reduce / chunked scan / dense tile)."""
    import jax
    from repro.core import balance as bal
    from repro.core import tocab
    from repro.obs.metrics import registry as _obs
    from .common import balance_mix_graph

    balance_block = 512  # finer blocks than the default suite → real spread
    graphs = {
        "rmat14": lambda: get_graph("rmat14")[0],
        "grid256": lambda: get_graph("grid256")[0],
        "balmix": balance_mix_graph,  # dense/medium/sparse by construction
    }
    for gname, build in graphs.items():
        g = build()
        bgb = build_blocked(g, block_size=balance_block)
        bgpb = build_blocked(g, block_size=balance_block, direction="push")
        x = jnp.ones((g.n,), jnp.float32)
        runs = {
            "pull/uniform": jax.jit(lambda v, b=bgb: tocab.tocab_pull(b, v)),
            "pull/balanced": jax.jit(
                lambda v, b=bgb: tocab.tocab_pull(b, v, schedule="balanced")),
            "push/uniform": jax.jit(lambda v, b=bgpb: tocab.tocab_push(b, v)),
            "push/balanced": jax.jit(
                lambda v, b=bgpb: tocab.tocab_push(b, v, schedule="balanced")),
        }
        times = {name: timeit(fn, x) for name, fn in runs.items()}
        for name, us in times.items():
            direction = name.split("/")[0]
            emit(f"fig8_balance/{gname}/{name}", us,
                 speedup=times[f"{direction}/uniform"] / us,
                 edges_per_s=g.m / (us * 1e-6))
        # Per-bin phase-2 timings (pull): how each strategy spends its time.
        summary = bgb.schedule.summary()
        for bin_id, bname in enumerate(bal.BIN_NAMES):
            info = summary[bname]
            if not info["blocks"]:
                continue
            fn = jax.jit(
                lambda v, b=bin_id: bal.bin_pull_partials(bgb, b, v))
            us = timeit(fn, x)
            eps = info["edges"] / max(us * 1e-6, 1e-12)
            _obs.histogram(
                "tocab.balance.bin_seconds", "per-bin phase-2 wall time"
            ).observe(us * 1e-6, bin=bname, graph=gname)
            _obs.gauge(
                "tocab.balance.bin_edges_per_s", "per-bin phase-2 throughput"
            ).set(eps, bin=bname, graph=gname)
            emit(f"fig8_balance/{gname}/bin/{bname}", us,
                 blocks=info["blocks"], edges=info["edges"],
                 rows=info["rows"], edges_per_s=eps)


def fig9_cache_missrate():
    """Fig. 9: L2 miss rate per variant (analytic LRU model, LLC scaled to
    the |V|·4B / capacity ratio of the paper's LiveJournal / 2.75MB)."""
    cfg = CacheConfig(capacity_bytes=64 * 1024, line_bytes=128, ways=16)
    for gname in ("rmat14", "rmat16"):
        g, *_ = get_graph(gname)
        for v in ("base", "cb", "tocab"):
            r = simulate_pagerank_variant(g, v, cfg, block_size=4096)
            emit(f"fig9/missrate/{gname}/{v}", 0.0,
                 miss_rate=r["miss_rate"],
                 cache_misses=r["cache_misses"],
                 cache_accesses=r["cache_accesses"])


def fig10_dram_per_edge():
    """Fig. 10: DRAM transactions per edge (GAIL metric)."""
    cfg = CacheConfig(capacity_bytes=64 * 1024, line_bytes=128, ways=16)
    for gname in ("rmat14", "rmat16"):
        g, *_ = get_graph(gname)
        base = simulate_pagerank_variant(g, "base", cfg, block_size=4096)
        for v in ("base", "cb", "tocab"):
            r = simulate_pagerank_variant(g, v, cfg, block_size=4096)
            emit(f"fig10/dram_per_edge/{gname}/{v}", 0.0,
                 dram_per_edge=r["dram_per_edge"],
                 dram_transactions=r["dram_transactions"],
                 vs_base=r["dram_per_edge"] / base["dram_per_edge"])


def fig11_blocksize():
    """Fig. 11: subgraph size ↔ performance trade-off, measured through the
    autotuner's trial runner (same warmup/median-of-k spans the tuner
    records) next to the cache model's prediction for each block size.
    Paper picks 256 vertices for a 2.75MB GPU L2; the sweep shows the same
    U-shape — and the row whose ``chosen=1`` is what ``schedule="auto"``
    would pick."""
    from repro.tune import Candidate, run_trial
    from repro.tune.analytic import predicted_cost

    g, _, _, _ = get_graph("rmat15")
    trials = []
    for bs in (256, 1024, 4096, 16384):
        c = Candidate(engine="tocab", direction="pull", block_size=bs)
        trials.append((bs, run_trial(g, c, workload="pagerank",
                                     graph_name="rmat15")))
    best_us = min(t.us for _, t in trials)
    for bs, t in trials:
        r = predicted_cost(g, t.candidate)
        emit(f"fig11/blocksize/{bs}", t.us,
             blocks=r["num_blocks"], miss_rate=r["miss_rate"],
             dram_per_edge=r["dram_per_edge"],
             edges_per_s=t.edges_per_s, chosen=int(t.us == best_us))


def table3_framework_comparison():
    """Table 3: averaged per-iteration PR time (ms) per graph ×
    {GC-pull, GC-push, Base(≈Gunrock-style flat)}."""
    for gname in SUITE:
        for v in ("gc-pull", "gc-push", "base"):
            us = _pr_iter_time(gname, v)
            emit(f"table3/pr_iter_ms/{gname}/{v}", us, ms=us / 1e3)


def table4_partition_counts():
    """Table 4: GraphCage LLC/VMEM-sized subgraphs vs CuSha-style
    scratchpad-sized shards (48KB / 8B per vertex entry)."""
    cusha_shard_vertices = 48 * 1024 // 8
    for gname in SUITE:
        g, _, bg, _ = get_graph(gname)
        gc_blocks = bg.num_blocks
        # CuSha CW format ≈ 2.5× CSR memory (paper §5)
        csr_bytes = 4 * (g.n + 1 + g.m * 2)
        emit(f"table4/partitions/{gname}", 0.0,
             graphcage_subgraphs=gc_blocks,
             cusha_shards=-(-g.n // cusha_shard_vertices),
             csr_mb=csr_bytes / 2**20,
             cusha_cw_mb=2.5 * csr_bytes / 2**20)


def ablation_blocking():
    """§3.1 design-choice ablation: 1D static TOCAB vs 2D blocking vs
    dynamic propagation blocking (the two alternatives the paper rejects),
    per-iteration SpMV wallclock + block counts."""
    import jax
    from repro.core.ablations import (
        build_blocked_2d, propagation_blocking_pull, tocab_pull_2d)
    from repro.core.tocab import baseline_pull, tocab_pull
    for gname in ("rmat14", "rmat15"):
        g, dg, bg, _ = get_graph(gname)
        x = jnp.ones((g.n,), jnp.float32)
        b2 = build_blocked_2d(g, block_size=BLOCK_SIZE)
        runs = {
            "base": jax.jit(lambda v: baseline_pull(dg, v)),
            "tocab_1d": jax.jit(lambda v: tocab_pull(bg, v)),
            "blocked_2d": jax.jit(lambda v: tocab_pull_2d(b2, v)),
            "prop_blocking": jax.jit(
                lambda v: propagation_blocking_pull(dg, v, num_bins=16)),
        }
        blocks = {"base": 1, "tocab_1d": bg.num_blocks,
                  "blocked_2d": b2.tiles_per_side ** 2, "prop_blocking": 16}
        for name, fn in runs.items():
            us = timeit(fn, x)
            emit(f"ablation/blocking/{gname}/{name}", us,
                 blocks=blocks[name])


ALL = [fig5_accum, fig6_pagerank, fig7_spmv, fig8_bc, fig8_balance,
       fig9_cache_missrate,
       fig10_dram_per_edge, fig11_blocksize,
       table3_framework_comparison, table4_partition_counts,
       ablation_blocking]

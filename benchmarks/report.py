"""Regenerate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
experiments/ JSON artifacts.  Run after dryrun/roofline sweeps:

    PYTHONPATH=src python -m benchmarks.report
"""
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load(d):
    recs = []
    p = os.path.join(ROOT, "experiments", d)
    if not os.path.isdir(p):
        return recs
    for f in sorted(os.listdir(p)):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(p, f))))
    return recs


def _fmt(x, digits=3):
    return f"{x:.{digits}e}" if isinstance(x, float) else str(x)


def dryrun_table() -> str:
    recs = _load("dryrun")
    lines = ["| arch | shape | mesh | compile | args/dev (GiB) | temp/dev (GiB) | HLO ops |",
             "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL: {r.get('error','?')} | | | |")
            continue
        temp = ""
        ma = r.get("memory_analysis") or ""
        if "temp_size_in_bytes=" in ma:
            t = int(ma.split("temp_size_in_bytes=")[1].split(",")[0])
            temp = f"{t/2**30:.2f}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']}s | {r['arg_bytes_per_device']/2**30:.2f} | "
            f"{temp} | {r['hlo_ops']} |")
    return "\n".join(lines)


def roofline_table() -> str:
    recs = _load("roofline")
    lines = ["| arch | shape | T_compute (s) | T_memory (s) | T_collective (s)"
             " | dominant | MODEL_FLOPs | useful frac | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['t_compute'])} | "
            f"{_fmt(r['t_memory'])} | {_fmt(r['t_collective'])} | "
            f"**{r['dominant']}** | {_fmt(r.get('model_flops', 0.0))} | "
            f"{r.get('useful_flop_frac', 0):.3f} | "
            f"{r.get('roofline_fraction', 0):.4f} |")
    return "\n".join(lines)


def bench_tables() -> str:
    """Render every BENCH_*.json under experiments/bench via repro.obs."""
    from repro.obs.report import render
    p = os.path.join(ROOT, "experiments", "bench")
    if not os.path.isdir(p):
        return "(no experiments/bench artifacts — run benchmarks.run first)"
    out = []
    for f in sorted(os.listdir(p)):
        if f.startswith("BENCH_") and f.endswith(".json"):
            out.append(render(json.load(open(os.path.join(p, f)))))
    return "\n\n".join(out) or "(no BENCH_*.json artifacts)"


if __name__ == "__main__":
    print("## Dry-run matrix\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod 16x16)\n")
    print(roofline_table())
    print("\n## Paper-figure benches\n")
    print(bench_tables())

"""Roofline harness (§Roofline deliverable): accurate three-term analysis
per (arch × shape) on the single-pod production mesh.

Method.  ``cost_analysis`` on a scan-over-layers module counts the while
body ONCE (XLA cost analysis has no trip counts), so LM cells are measured
with a **two-point unrolled fit**: compile the model unrolled at depths
L₁ < L₂ (small, fast), fit the exact per-layer slope of every quantity
(FLOPs, bytes, collective wire bytes), and extrapolate to the full depth —
exact for depth-linear programs, which scan models are by construction.
The vocab/embedding intercept is captured by the fit's constant term.
GNN / recsys models are python-unrolled already → measured directly.

Run:  PYTHONPATH=src python -m benchmarks.roofline [--arch A --shape S]
Writes experiments/roofline/<arch>__<shape>.json + a summary table.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

import jax

from repro.configs import all_cells, get_arch
from repro.dist.sharding import use_mesh_rules
from repro.launch.cells import build_cell
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "roofline")


def _measure(arch_id, shape_name, mesh, overrides=None):
    with use_mesh_rules(mesh):
        cell = build_cell(arch_id, shape_name, mesh, overrides=overrides)
        compiled = jax.jit(cell.fn).lower(*cell.args).compile()
    n = mesh.devices.size
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # jax < 0.5 returns [dict]
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text(), n)
    return {
        "flops": float(cost.get("flops", 0.0)) * n,
        "bytes": float(cost.get("bytes accessed", 0.0)) * n,
        "wire": coll.wire_bytes,
        "coll_bytes": coll.total_bytes,
        "counts": coll.counts,
        "model_flops": cell.model_flops,
    }


def measure_cell(arch_id: str, shape_name: str, mesh) -> dict:
    spec = get_arch(arch_id)
    if spec.family != "lm":
        return _measure(arch_id, shape_name, mesh)
    cfg = spec.make_model_cfg()
    step = 2 if cfg.pair_scan else 1
    l1, l2 = 2 * step, 4 * step
    m1 = _measure(arch_id, shape_name, mesh,
                  overrides={"use_scan": False, "n_layers": l1})
    m2 = _measure(arch_id, shape_name, mesh,
                  overrides={"use_scan": False, "n_layers": l2})
    L = cfg.n_layers
    out = {"counts": {}}
    for k in ("flops", "bytes", "wire", "coll_bytes"):
        slope = (m2[k] - m1[k]) / (l2 - l1)
        out[k] = m1[k] + slope * (L - l1)
    for k, v1 in m1["counts"].items():
        slope = (m2["counts"][k] - v1) / (l2 - l1)
        out["counts"][k] = round(v1 + slope * (L - l1))
    # model_flops of the FULL config (not the shallow fit points)
    with use_mesh_rules(mesh):
        full = build_cell(arch_id, shape_name, mesh)
    out["model_flops"] = full.model_flops
    out["fit_points"] = {"l1": l1, "l2": l2, "flops_l1": m1["flops"],
                         "flops_l2": m2["flops"]}
    return out


def analyse(arch_id: str, shape_name: str, mesh=None,
            overrides=None) -> dict:
    mesh = mesh or make_production_mesh()
    n = mesh.devices.size
    t0 = time.time()
    if overrides is None:
        m = measure_cell(arch_id, shape_name, mesh)
    else:  # §Perf variants measure directly with explicit overrides
        m = _measure(arch_id, shape_name, mesh, overrides=overrides)

    class _C:  # tiny shim for roofline_terms
        wire_bytes = m["wire"]
        counts = m["counts"]

        @property
        def total_bytes(self):
            return m["coll_bytes"]

    rl = roofline_terms(m["flops"], m["bytes"], _C(), n,
                        model_flops=m["model_flops"])
    rl.pop("wire_bytes", None)
    rec = dict(arch=arch_id, shape=shape_name, num_devices=int(n),
               hlo_flops=m["flops"], hlo_bytes=m["bytes"],
               wire_bytes=m["wire"], elapsed_s=round(time.time() - t0, 1),
               **{k: v for k, v in rl.items()})
    if "fit_points" in m:
        rec["fit_points"] = m["fit_points"]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cells = ([(args.arch, args.shape)] if args.arch else
             [(a, c.name) for a, c, _ in all_cells()])
    for arch_id, shape_name in cells:
        try:
            rec = analyse(arch_id, shape_name)
            path = os.path.join(args.out, f"{arch_id}__{shape_name}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            print(f"{arch_id:22s} {shape_name:14s} dom={rec['dominant']:10s} "
                  f"T_c={rec['t_compute']:.3e} T_m={rec['t_memory']:.3e} "
                  f"T_x={rec['t_collective']:.3e} "
                  f"roofline={rec.get('roofline_fraction', 0):.3f}")
        except Exception as e:
            print(f"{arch_id:22s} {shape_name:14s} FAILED: {e}")


if __name__ == "__main__":
    main()

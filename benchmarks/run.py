"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig6]``
prints ``name,us_per_call,derived`` CSV rows.

The roofline sweep (§Roofline) is separate — it needs 512 fake devices:
``PYTHONPATH=src python -m benchmarks.roofline``.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark fn names")
    args = ap.parse_args()
    from . import paper_figs
    print("name,us_per_call,derived")
    t0 = time.time()
    for fn in paper_figs.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        print(f"# --- {fn.__name__}: {fn.__doc__.splitlines()[0]}",
              file=sys.stderr)
        fn()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig6]``
prints ``name,us_per_call,derived`` CSV rows on stdout and, per figure,
writes a schema-versioned ``BENCH_<fig>.json`` artifact (structured
records + run fingerprint + metric-registry snapshot) under
``experiments/bench/``.  Render or diff those with::

    python -m repro.obs.report experiments/bench/BENCH_fig6_pagerank.json \
        [--baseline old/BENCH_fig6_pagerank.json]

The roofline sweep (§Roofline) is separate — it needs 512 fake devices:
``PYTHONPATH=src python -m benchmarks.roofline``.
"""
import argparse
import os
import sys
import time

from repro.obs import export, trace as obs_trace
from repro.obs.metrics import registry

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "experiments", "bench")


def run_one(fn, out_dir: str) -> dict:
    """Run one figure function and write its BENCH_<name>.json artifact."""
    from . import common
    common.drain_records()
    with obs_trace.span(f"bench.{fn.__name__}"):
        fn()
    records = common.drain_records()
    payload = export.bench_payload(fn.__name__, records,
                                   metrics=registry.snapshot())
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{fn.__name__}.json")
    export.write_json(path, payload)
    print(f"# wrote {os.path.relpath(path, ROOT)} "
          f"({len(records)} records)", file=sys.stderr)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark fn names")
    ap.add_argument("--out-dir", default=DEFAULT_OUT,
                    help="directory for BENCH_<fig>.json artifacts")
    ap.add_argument("--list", action="store_true",
                    help="list benchmark fn names and exit")
    ap.add_argument("--chaos", default=None, metavar="SEED:RATE",
                    help="arm deterministic fault injection at the default "
                         "sites (repro.resilience.chaos) for the whole run")
    args = ap.parse_args()
    if args.chaos:
        from repro.resilience import chaos
        chaos.configure_spec(args.chaos)
    from . import paper_figs
    if args.list:
        for fn in paper_figs.ALL:
            doc = (fn.__doc__ or fn.__name__).splitlines()[0]
            print(f"{fn.__name__}: {doc}")
        return
    print("name,us_per_call,derived")
    t0 = time.time()
    for fn in paper_figs.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        doc = (fn.__doc__ or fn.__name__).splitlines()[0]
        print(f"# --- {fn.__name__}: {doc}", file=sys.stderr)
        run_one(fn, args.out_dir)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Shared benchmark utilities: graph suite, timing, structured records.

``emit`` both prints the legacy CSV row *and* appends a structured record
to ``RECORDS`` — the per-figure harness in ``benchmarks.run`` drains that
list into a schema-versioned ``BENCH_<fig>.json`` via ``repro.obs.export``.
"""
from __future__ import annotations

import time

import jax

from repro.core import (
    DeviceGraph, Graph, build_blocked, from_edges, grid_graph, rmat_graph,
)
from repro.obs.metrics import registry as _obs

# Scaled-down analogue of the paper's Table 2 suite (CPU container):
# scale-free RMAT graphs with permuted ids (poor locality) + one
# good-locality control (grid, standing in for Hollywood).
SUITE = {
    "rmat14": lambda: rmat_graph(14, 8, seed=1, weights=True),
    "rmat15": lambda: rmat_graph(15, 8, seed=2, weights=True),
    "rmat16": lambda: rmat_graph(16, 8, seed=3, weights=True),
    "grid256": lambda: _weighted_grid(256),
}

BLOCK_SIZE = 2048  # default TOCAB block for the CPU-scale suite

#: the graph CI smoke jobs (fig6 smoke, tune-smoke) exercise — smallest
#: scale-free member of the suite
SMOKE_GRAPH = "rmat14"


def _weighted_grid(side):
    import numpy as np
    g = grid_graph(side, side)
    rng = np.random.default_rng(0)
    return Graph(g.n, g.rowptr, g.colidx,
                 rng.random(g.m, dtype=np.float32))


def balance_mix_graph(n: int = 16384, deg: int = 24, seed: int = 0) -> Graph:
    """Mixed-density graph for the load-balancing benchmark (fig8_balance).

    Destination concentration varies by source range, so TOCAB blocks (source
    ranges in pull) land in genuinely different sparsity bins: the first
    quarter of sources targets 64 hub destinations (dense blocks — high
    edges-per-row after compaction), the next quarter a 1k pool (medium),
    and the rest target uniformly random destinations (sparse)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    q = n // 4
    srcs, dsts = [], []
    for lo, hi, pool in ((0, q, 64), (q, 2 * q, 1024), (2 * q, n, n)):
        src = np.repeat(np.arange(lo, hi), deg)
        dst = rng.integers(0, pool, src.shape[0])
        srcs.append(src)
        dsts.append(dst)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    keep = src != dst
    vals = rng.random(int(keep.sum()), dtype=np.float32)
    return from_edges(n, src[keep], dst[keep], vals=vals, dedup=True)


_CACHE: dict = {}


def tuned_block_config(g, name: str):
    """(block_size, bin_thresholds, source) for a suite graph.

    Consults the persistent tuning DB (``experiments/tune/TUNE_DB.json``)
    so figure sweeps start from the tuned layout instead of the hard-coded
    ``BLOCK_SIZE``; untuned graphs (fresh checkouts, CI perf gate) fall
    back to the defaults — the DB is not committed, so gate baselines are
    unaffected."""
    try:
        from repro.tune.plan import resolve_plan

        plan = resolve_plan(g, workload="pagerank")
    except Exception:
        plan = None
    if plan is None or not plan.candidate.blocked:
        return BLOCK_SIZE, None, "default"
    c = plan.candidate
    return c.block_size, c.bin_thresholds, plan.source


def get_graph(name: str):
    if name not in _CACHE:
        g = SUITE[name]()
        block_size, thresholds, source = tuned_block_config(g, name)
        if source != "default":
            print(f"# {name}: tuned layout block_size={block_size} "
                  f"bin_thresholds={thresholds} ({source})")
        _obs.gauge("bench.block_size", "TOCAB block size the figure "
                   "sweeps build with (tuned when the DB has an entry)"
                   ).set(block_size, graph=name, source=source)
        kw = {} if thresholds is None else {"bin_thresholds": thresholds}
        _CACHE[name] = (
            g,
            DeviceGraph.from_host(g),
            build_blocked(g, block_size=block_size, direction="pull", **kw),
            build_blocked(g, block_size=block_size, direction="push", **kw),
        )
    return _CACHE[name]


def timeit(fn, *args, reps: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall-time (µs) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


RECORDS: list = []  # structured rows of the currently-running figure


def emit(name: str, us: float, **fields):
    """Record one benchmark row.

    Prints the legacy ``name,us_per_call,derived`` CSV line and appends
    ``{"name", "us_per_call", **fields}`` to ``RECORDS``.  Numeric fields
    also land in the process metric registry as ``bench.<field>`` gauges
    labelled by record name, so exports tie benches to runtime counters."""
    derived = ",".join(
        f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
        for k, v in fields.items())
    print(f"{name},{us:.1f},{derived}")
    rec = {"name": name, "us_per_call": us, **fields}
    RECORDS.append(rec)
    if us:
        _obs.histogram("bench.us_per_call", "benchmark record runtimes") \
            .observe(us, name=name)
    for k, v in fields.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            _obs.gauge(f"bench.{k}", "benchmark derived field").set(v, name=name)


def drain_records() -> list:
    """Return and clear the structured rows accumulated since last drain."""
    out = list(RECORDS)
    RECORDS.clear()
    return out
